//! Experiment configuration: every hyperparameter of the paper's §5 setup
//! in one struct, with named presets and a TOML-subset file loader
//! (`key = value` lines, `[section]` headers flatten to `section.key`).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::cli::Args;

/// Reward weight settings of §5: W1 (conservative) and W2 (aggressive).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Weights {
    pub w1: f64, // accuracy weight
    pub w2: f64, // precision (cost) weight
    pub w3: f64, // penalty weight (paper §4.2: "one can also enforce a weight w3
                 // on this term to avoid hiding the effect of other terms";
                 // calibrated to 0.5 — EXPERIMENTS.md §Calibration)
}

impl Weights {
    pub const W1: Weights = Weights { w1: 1.0, w2: 0.1, w3: 0.25 };
    pub const W2: Weights = Weights { w1: 1.0, w2: 1.0, w3: 0.25 };

    pub fn by_name(name: &str) -> Result<Weights> {
        match name {
            "W1" | "w1" => Ok(Weights::W1),
            "W2" | "w2" => Ok(Weights::W2),
            _ => bail!("unknown weight setting {name:?} (use W1 or W2)"),
        }
    }
}

/// Full experiment configuration (defaults = paper §5 settings).
#[derive(Clone, Debug)]
pub struct Config {
    // ---- dataset (§5.1) ----
    pub n_train: usize,
    pub n_test: usize,
    pub size_min: usize,
    pub size_max: usize,
    pub kappa_log10_min: f64,
    pub kappa_log10_max: f64,
    pub sparsity: f64,     // λ_s for the sparse generator (§5.3)
    pub sparse_beta: f64,  // diagonal shift β
    pub seed: u64,

    // ---- features / discretization (§4.2) ----
    pub bins_kappa: usize, // n1
    pub bins_norm: usize,  // n2
    /// n3 — φ₃ residual-decay bins for the per-step MDP (DESIGN.md §2i).
    /// Only consulted when `per_step` is on; the static path always
    /// trains with a single decay bin, which makes its state indices
    /// bit-identical to the historical 2-D layout.
    pub bins_decay: usize,
    pub delta_c: f64,
    pub delta_n: f64,

    // ---- bandit (§3.2) ----
    pub episodes: usize,     // T
    pub alpha: f64,          // learning rate (0 => 1/N(s,a) schedule)
    pub eps_min: f64,        // minimum exploration
    pub k_top: usize,        // 0 => keep all reduced actions (35)
    pub weights: Weights,
    /// Solver-family routing of the action space (DESIGN.md §2d):
    /// "auto" trains all-SPD datasets over both families (LU-IR ×
    /// CG-IR); "lu-only" pins the paper's LU-only space everywhere
    /// (the §5.3 repro tables use this for fidelity).
    pub families: String,
    /// Opt-in to the v3 grown arms (block-Jacobi / SSOR preconditioned
    /// CG and restarted GMRES) in the trained action space. Off by
    /// default so legacy spaces, indices, and policies stay untouched.
    pub precond_arms: bool,
    /// Opt-in to the per-step precision MDP: the policy re-decides the
    /// precision tuple at every IR iteration from the φ₃ residual-decay
    /// bin. Off ⇒ every solve routes through the static (contextual
    /// bandit) path, bit-identical to pre-v3 builds.
    pub per_step: bool,

    // ---- reward (eq. 21–25) ----
    pub c1: f64,
    pub theta: f64,        // truncation threshold (paper: 2.5)
    pub acc_eps: f64,      // ε in eq. 24 (paper: 1e-10)
    pub penalty_enabled: bool,
    pub fail_reward: f64,  // reward on solver failure

    // ---- solver (§4.1) ----
    pub tau: f64,          // convergence tolerance τ (1e-6 / 1e-8)
    pub stag_ratio: f64,   // legacy/extra guard (eq. 15 now uses tau itself)
    pub max_outer: usize,  // i_max
    pub gmres_max_m: usize,
    pub gmres_tol_factor: f64, // inner tol = factor * tau
    /// Acceptance bar for degradation-ladder retries in the serving
    /// facade: a rescue rung's result is taken only if its backward
    /// error is at or below this, so a fallback can never silently
    /// return garbage (ISSUE 6).
    pub ladder_nbe_max: f64,

    // ---- evaluation (eq. 28–30) ----
    pub tau_base: f64,

    // ---- runtime ----
    pub artifacts_dir: String,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            n_train: 100,
            n_test: 100,
            size_min: 100,
            size_max: 500,
            kappa_log10_min: 1.0,
            kappa_log10_max: 9.0,
            sparsity: 0.01,
            sparse_beta: 1e-8,
            seed: 20260710,
            bins_kappa: 10,
            bins_norm: 10,
            bins_decay: 3,
            delta_c: 1.0,
            delta_n: 1e-30,
            episodes: 100,
            alpha: 0.5,
            eps_min: 0.05,
            k_top: 9, // §5: "one-fourth of the valid precision combinations"
            weights: Weights::W1,
            families: "auto".to_string(),
            precond_arms: false,
            per_step: false,
            c1: 1.0,
            theta: 2.5,
            acc_eps: 1e-10,
            penalty_enabled: true,
            fail_reward: -10.0,
            tau: 1e-6,
            stag_ratio: 0.9,
            max_outer: 10,
            gmres_max_m: 50,
            gmres_tol_factor: 1.0,
            ladder_nbe_max: 1e-6,
            tau_base: 1e-8,
            artifacts_dir: "artifacts".to_string(),
        }
    }
}

impl Config {
    /// Paper-scale preset (the default).
    pub fn paper() -> Config {
        Config::default()
    }

    /// Scaled-down preset for quick runs / CI (same structure, ~8x less
    /// work: fewer/smaller systems, fewer episodes).
    pub fn small() -> Config {
        Config {
            n_train: 30,
            n_test: 30,
            size_min: 60,
            size_max: 200,
            episodes: 40,
            ..Config::default()
        }
    }

    /// Minimal preset for unit/integration tests.
    pub fn tiny() -> Config {
        Config {
            n_train: 8,
            n_test: 8,
            size_min: 24,
            size_max: 64,
            episodes: 10,
            bins_kappa: 4,
            bins_norm: 4,
            ..Config::default()
        }
    }

    pub fn preset(name: &str) -> Result<Config> {
        match name {
            "paper" => Ok(Config::paper()),
            "small" => Ok(Config::small()),
            "tiny" => Ok(Config::tiny()),
            _ => bail!("unknown preset {name:?} (paper|small|tiny)"),
        }
    }

    /// Load `key = value` / `[section]` TOML-subset file.
    pub fn from_file(path: &str) -> Result<Config> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let kv = parse_kv(&text)?;
        let mut cfg = match kv.get("preset") {
            Some(p) => Config::preset(trim_quotes(p))?,
            None => Config::default(),
        };
        for (k, v) in &kv {
            if k != "preset" {
                cfg.set(k, v)?;
            }
        }
        Ok(cfg)
    }

    /// Apply CLI overrides: `--config file.toml`, `--preset small`,
    /// `--set key=value` (repeatable via comma list) plus first-class
    /// options (`--tau`, `--episodes`, `--weights`, `--seed`...).
    pub fn from_args(args: &Args) -> Result<Config> {
        let mut cfg = if let Some(path) = args.get("config") {
            Config::from_file(path)?
        } else if let Some(p) = args.get("preset") {
            Config::preset(p)?
        } else {
            Config::default()
        };
        if let Some(list) = args.get("set") {
            for item in list.split(',') {
                let (k, v) = item
                    .split_once('=')
                    .ok_or_else(|| anyhow!("--set expects key=value, got {item:?}"))?;
                cfg.set(k.trim(), v.trim())?;
            }
        }
        for key in [
            "tau", "alpha", "eps-min", "episodes", "seed", "weights", "k-top",
            "n-train", "n-test", "tau-base", "artifacts-dir", "size-min", "size-max",
            "families",
        ] {
            if let Some(v) = args.get(key) {
                cfg.set(&key.replace('-', "_"), v)?;
            }
        }
        if args.flag("no-penalty") {
            cfg.penalty_enabled = false;
        }
        if args.flag("per-step") {
            cfg.per_step = true;
        }
        if args.flag("precond") {
            cfg.precond_arms = true;
        }
        Ok(cfg)
    }

    /// Set one field by (snake_case) name.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let v = trim_quotes(value);
        macro_rules! num {
            () => {
                v.parse().map_err(|e| anyhow!("{key}={v:?}: {e}"))?
            };
        }
        match key {
            "n_train" => self.n_train = num!(),
            "n_test" => self.n_test = num!(),
            "size_min" => self.size_min = num!(),
            "size_max" => self.size_max = num!(),
            "kappa_log10_min" => self.kappa_log10_min = num!(),
            "kappa_log10_max" => self.kappa_log10_max = num!(),
            "sparsity" => self.sparsity = num!(),
            "sparse_beta" => self.sparse_beta = num!(),
            "seed" => self.seed = num!(),
            "bins_kappa" => self.bins_kappa = num!(),
            "bins_norm" => self.bins_norm = num!(),
            "bins_decay" => self.bins_decay = num!(),
            "delta_c" => self.delta_c = num!(),
            "delta_n" => self.delta_n = num!(),
            "episodes" => self.episodes = num!(),
            "alpha" => self.alpha = num!(),
            "eps_min" => self.eps_min = num!(),
            "k_top" => self.k_top = num!(),
            "weights" => self.weights = Weights::by_name(v)?,
            "families" => match v {
                "auto" | "lu-only" => self.families = v.to_string(),
                _ => bail!("unknown families setting {v:?} (auto|lu-only)"),
            },
            "precond_arms" => self.precond_arms = v == "true" || v == "1",
            "per_step" => self.per_step = v == "true" || v == "1",
            "c1" => self.c1 = num!(),
            "theta" => self.theta = num!(),
            "acc_eps" => self.acc_eps = num!(),
            "penalty_enabled" => self.penalty_enabled = v == "true" || v == "1",
            "fail_reward" => self.fail_reward = num!(),
            "tau" => self.tau = num!(),
            "stag_ratio" => self.stag_ratio = num!(),
            "max_outer" => self.max_outer = num!(),
            "gmres_max_m" => self.gmres_max_m = num!(),
            "gmres_tol_factor" => self.gmres_tol_factor = num!(),
            "ladder_nbe_max" => self.ladder_nbe_max = num!(),
            "tau_base" => self.tau_base = num!(),
            "artifacts_dir" => self.artifacts_dir = v.to_string(),
            _ => bail!("unknown config key {key:?}"),
        }
        Ok(())
    }
}

fn trim_quotes(s: &str) -> &str {
    s.trim().trim_matches('"').trim_matches('\'')
}

fn parse_kv(text: &str) -> Result<BTreeMap<String, String>> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
            section = name.trim().to_string();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{section}.{}", k.trim())
        };
        out.insert(key, v.trim().to_string());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = Config::default();
        assert_eq!(c.episodes, 100);
        assert_eq!(c.n_train, 100);
        assert_eq!((c.bins_kappa, c.bins_norm), (10, 10));
        assert_eq!(c.alpha, 0.5);
        assert_eq!(c.theta, 2.5);
        assert_eq!(c.size_min, 100);
        assert_eq!(c.size_max, 500);
    }

    #[test]
    fn weight_presets() {
        assert_eq!(Weights::by_name("W1").unwrap(), Weights { w1: 1.0, w2: 0.1, w3: 0.25 });
        assert_eq!(Weights::by_name("W2").unwrap(), Weights { w1: 1.0, w2: 1.0, w3: 0.25 });
        assert!(Weights::by_name("W9").is_err());
    }

    #[test]
    fn set_and_reject() {
        let mut c = Config::default();
        c.set("tau", "1e-8").unwrap();
        assert_eq!(c.tau, 1e-8);
        c.set("weights", "W2").unwrap();
        assert_eq!(c.weights, Weights::W2);
        c.set("families", "lu-only").unwrap();
        assert_eq!(c.families, "lu-only");
        assert!(c.set("families", "qr-only").is_err());
        assert!(!c.per_step && !c.precond_arms, "v3 knobs default off");
        c.set("per_step", "1").unwrap();
        c.set("precond_arms", "true").unwrap();
        c.set("bins_decay", "4").unwrap();
        assert!(c.per_step && c.precond_arms);
        assert_eq!(c.bins_decay, 4);
        assert!(c.set("nope", "1").is_err());
        assert!(c.set("tau", "xyz").is_err());
    }

    #[test]
    fn from_file_roundtrip() {
        let dir = std::env::temp_dir().join("pa_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.toml");
        std::fs::write(
            &path,
            "preset = \"small\"\ntau = 1e-8  # stricter\nweights = \"W2\"\n",
        )
        .unwrap();
        let c = Config::from_file(path.to_str().unwrap()).unwrap();
        assert_eq!(c.n_train, 30); // from preset
        assert_eq!(c.tau, 1e-8);
        assert_eq!(c.weights, Weights::W2);
    }

    #[test]
    fn from_args_overrides() {
        let args = crate::util::cli::Args::parse(
            ["train", "--preset", "tiny", "--tau", "1e-8", "--set", "alpha=0.25,theta=3.0", "--no-penalty"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        let c = Config::from_args(&args).unwrap();
        assert_eq!(c.n_train, 8);
        assert_eq!(c.tau, 1e-8);
        assert_eq!(c.alpha, 0.25);
        assert_eq!(c.theta, 3.0);
        assert!(!c.penalty_enabled);
    }
}
