//! Atomic filesystem writes (write-tmp-then-rename).
//!
//! Every artifact the repo persists and later parses loudly — policy
//! JSON, bench baselines, daemon snapshots — must never be observable
//! half-written: a crash mid-`std::fs::write` leaves a truncated file
//! that `TrainedPolicy::from_json` rejects, and a reader racing the
//! writer sees a prefix. `atomic_write` closes both windows: the bytes
//! go to a sibling `.tmp` file first and only an atomic `rename` (same
//! directory, hence same filesystem) makes them visible under the final
//! name. Readers see either the old complete file or the new complete
//! file, never a mixture.

use anyhow::{Context, Result};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide counter so concurrent writers to the same destination
/// never collide on the temp name (each rename is still last-writer-wins
/// on the final path, which is the semantics we want).
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// Write `bytes` to `path` atomically: create parent directories, write
/// a unique sibling temp file, then rename it over `path`.
pub fn atomic_write(path: &str, bytes: &[u8]) -> Result<()> {
    let dest = Path::new(path);
    if let Some(dir) = dest.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating directory for {path}"))?;
        }
    }
    let seq = TMP_SEQ.fetch_add(1, Ordering::Relaxed);
    let tmp = dest.with_extension(format!("tmp.{}.{seq}", std::process::id()));
    std::fs::write(&tmp, bytes)
        .with_context(|| format!("writing temp file {}", tmp.display()))?;
    match std::fs::rename(&tmp, dest) {
        Ok(()) => Ok(()),
        Err(e) => {
            // don't leave the temp file behind on a failed rename
            let _ = std::fs::remove_file(&tmp);
            Err(e).with_context(|| format!("renaming {} -> {path}", tmp.display()))
        }
    }
}

/// [`atomic_write`] for string payloads (the common JSON case).
pub fn atomic_write_str(path: &str, text: &str) -> Result<()> {
    atomic_write(path, text.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!(
            "pa_fsx_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn writes_bytes_and_creates_parents() {
        let d = tmp_dir("parents");
        let path = d.join("a/b/c.json");
        let path = path.to_str().unwrap();
        atomic_write(path, b"{\"k\":1}").unwrap();
        assert_eq!(std::fs::read(path).unwrap(), b"{\"k\":1}");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn replaces_existing_file_completely() {
        let d = tmp_dir("replace");
        let path = d.join("p.json");
        let path = path.to_str().unwrap();
        atomic_write_str(path, "old-content-that-is-longer").unwrap();
        atomic_write_str(path, "new").unwrap();
        assert_eq!(std::fs::read_to_string(path).unwrap(), "new");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn leaves_no_temp_files_behind() {
        let d = tmp_dir("clean");
        let path = d.join("p.json");
        for i in 0..4 {
            atomic_write_str(path.to_str().unwrap(), &format!("v{i}")).unwrap();
        }
        let names: Vec<String> = std::fs::read_dir(&d)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names, vec!["p.json".to_string()], "{names:?}");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn rename_failure_is_loud_and_cleans_temp() {
        let d = tmp_dir("fail");
        // destination is a non-empty directory -> rename must fail
        let dest = d.join("blocked");
        std::fs::create_dir_all(dest.join("inner")).unwrap();
        let err = atomic_write_str(dest.to_str().unwrap(), "x").unwrap_err();
        assert!(format!("{err:#}").contains("renaming"), "{err:#}");
        let leftovers: Vec<String> = std::fs::read_dir(&d)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .filter(|n| n != "blocked")
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
        let _ = std::fs::remove_dir_all(&d);
    }
}
