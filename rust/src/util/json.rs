//! Minimal JSON reader/writer (serde is unavailable offline — DESIGN.md §6).
//!
//! Used for: the AOT `artifacts/manifest.json`, Q-table persistence, and
//! the cross-language chop golden vectors. Numbers round-trip exactly:
//! the writer emits the shortest representation that parses back to the
//! same f64 (Rust's `{:?}` float formatting).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Objects preserve no insertion order (BTreeMap) — fine
/// for our usage and keeps output deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(x) => Ok(*x),
            _ => bail!("expected number, got {self:?}"),
        }
    }
    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("expected non-negative integer, got {x}");
        }
        Ok(x as usize)
    }
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }
    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }
    pub fn get(&self, key: &str) -> Result<&Value> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(x) => {
                if x.is_finite() {
                    // {:?} prints the shortest string that round-trips.
                    let _ = write!(out, "{x:?}");
                } else {
                    // JSON has no inf/nan; encode as strings the parser
                    // (ours) maps back — only used by our own files.
                    let _ = write!(
                        out,
                        "\"{}\"",
                        if x.is_nan() {
                            "__nan__"
                        } else if *x > 0.0 {
                            "__inf__"
                        } else {
                            "__-inf__"
                        }
                    );
                }
            }
            Value::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Value::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Value::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn arr(values: Vec<Value>) -> Value {
    Value::Arr(values)
}
pub fn num(x: f64) -> Value {
    Value::Num(x)
}
pub fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}
pub fn num_arr(xs: &[f64]) -> Value {
    Value::Arr(xs.iter().map(|&x| Value::Num(x)).collect())
}

pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser {
        b: text.as_bytes(),
        i: 0,
    };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        bail!("trailing characters at offset {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }
    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }
    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected {:?} at offset {}, found {:?}",
                c as char,
                self.i,
                self.peek()? as char
            );
        }
        self.i += 1;
        Ok(())
    }
    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => {
                let st = self.string()?;
                // our inf/nan encoding
                Ok(match st.as_str() {
                    "__inf__" => Value::Num(f64::INFINITY),
                    "__-inf__" => Value::Num(f64::NEG_INFINITY),
                    "__nan__" => Value::Num(f64::NAN),
                    _ => Value::Str(st),
                })
            }
            b't' => {
                self.lit("true")?;
                Ok(Value::Bool(true))
            }
            b'f' => {
                self.lit("false")?;
                Ok(Value::Bool(false))
            }
            b'n' => {
                self.lit("null")?;
                Ok(Value::Null)
            }
            b'N' => {
                // python json.dump emits bare NaN/Infinity by default
                self.lit("NaN")?;
                Ok(Value::Num(f64::NAN))
            }
            b'I' => {
                self.lit("Infinity")?;
                Ok(Value::Num(f64::INFINITY))
            }
            b'-' if self.b.get(self.i + 1) == Some(&b'I') => {
                self.i += 1;
                self.lit("Infinity")?;
                Ok(Value::Num(f64::NEG_INFINITY))
            }
            _ => self.number(),
        }
    }
    fn lit(&mut self, word: &str) -> Result<()> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(())
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }
    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                c => bail!("expected ',' or '}}' at offset {}, found {:?}", self.i, c as char),
            }
        }
    }
    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(v));
                }
                c => bail!("expected ',' or ']' at offset {}, found {:?}", self.i, c as char),
            }
        }
    }
    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at offset {}", self.i),
                    }
                }
                c => {
                    // Re-borrow the full UTF-8 char.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let chunk = std::str::from_utf8(&self.b[start..start + len])?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }
    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(txt.parse::<f64>().map_err(|e| {
            anyhow!("bad number {txt:?} at offset {start}: {e}")
        })?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basics() {
        let v = obj(vec![
            ("a", num(1.5)),
            ("b", arr(vec![num(1.0), Value::Bool(true), Value::Null])),
            ("c", s("hi \"there\"\n")),
        ]);
        let text = v.to_string();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn exact_float_roundtrip() {
        for &x in &[
            0.1,
            1.0 / 3.0,
            f64::MIN_POSITIVE,
            5e-324,
            1.7976931348623157e308,
            -2.2250738585072014e-308,
            123456789.123456789,
        ] {
            let text = Value::Num(x).to_string();
            assert_eq!(parse(&text).unwrap().as_f64().unwrap(), x, "{text}");
        }
    }

    #[test]
    fn inf_nan_roundtrip() {
        let v = num_arr(&[f64::INFINITY, f64::NEG_INFINITY, f64::NAN]);
        let text = v.to_string();
        let back = parse(&text).unwrap();
        let xs = back.as_arr().unwrap();
        assert!(xs[0].as_f64().unwrap().is_infinite());
        assert!(xs[1].as_f64().unwrap() < 0.0);
        assert!(xs[2].as_f64().unwrap().is_nan());
    }

    #[test]
    fn parses_python_json_dump_output() {
        let text = r#"{"version": 1, "artifacts": [{"name": "lu", "shape": [64, 64], "ok": true}], "x": NaN, "y": Infinity, "z": -Infinity}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("version").unwrap().as_usize().unwrap(), 1);
        assert!(v.get("x").unwrap().as_f64().unwrap().is_nan());
        assert_eq!(
            v.get("artifacts").unwrap().as_arr().unwrap()[0]
                .get("name")
                .unwrap()
                .as_str()
                .unwrap(),
            "lu"
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("hello").is_err());
        assert!(parse("{\"a\": 1} x").is_err());
    }

    #[test]
    fn unicode_strings() {
        let v = s("héllo ☃ \u{1F600}");
        let text = v.to_string();
        assert_eq!(parse(&text).unwrap(), v);
        assert_eq!(
            parse(r#""Aé""#).unwrap().as_str().unwrap(),
            "Aé"
        );
    }
}
