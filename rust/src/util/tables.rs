//! Table / figure emitters: markdown tables in the paper's formatting
//! (errors to 2 significant digits, `x.yz e-k`), CSV series for figures,
//! and a small ASCII scatter plot used by the Figure-3 regenerator.

use std::fmt::Write as _;

/// Format a value like the paper's tables: 2 significant digits,
/// scientific notation ("1.19e-14"). NaN/inf/dashes handled.
pub fn sci2(x: f64) -> String {
    if x.is_nan() {
        return "-".to_string();
    }
    if x.is_infinite() {
        return if x > 0.0 { "inf" } else { "-inf" }.to_string();
    }
    if x == 0.0 {
        return "0.0".to_string();
    }
    format!("{:.2e}", x)
}

/// Fixed-point with 2 decimals (iteration counts etc.).
pub fn fix2(x: f64) -> String {
    if x.is_nan() {
        "-".to_string()
    } else {
        format!("{x:.2}")
    }
}

/// Percentage with one decimal ("89.2%").
pub fn pct(x: f64) -> String {
    if x.is_nan() {
        "-".to_string()
    } else {
        format!("{:.1}%", 100.0 * x)
    }
}

/// Render a markdown table.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "\n### {}\n", self.title);
        }
        let line = |cells: &[String], width: &[usize], out: &mut String| {
            let _ = write!(out, "|");
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, " {:<w$} |", c, w = width[i]);
            }
            let _ = writeln!(out);
        };
        line(&self.headers, &width, &mut out);
        let _ = write!(out, "|");
        for w in &width {
            let _ = write!(out, "{}|", "-".repeat(w + 2));
        }
        let _ = writeln!(out);
        for row in &self.rows {
            line(row, &width, &mut out);
        }
        out
    }

    /// Also emit machine-readable CSV.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let _ = writeln!(
            out,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }
}

/// Write CSV columns (figure series).
pub fn write_csv(path: &str, headers: &[&str], columns: &[&[f64]]) -> anyhow::Result<()> {
    assert_eq!(headers.len(), columns.len());
    let n = columns.iter().map(|c| c.len()).max().unwrap_or(0);
    let mut out = String::new();
    let _ = writeln!(out, "{}", headers.join(","));
    for i in 0..n {
        let row: Vec<String> = columns
            .iter()
            .map(|c| c.get(i).map(|x| format!("{x:?}")).unwrap_or_default())
            .collect();
        let _ = writeln!(out, "{}", row.join(","));
    }
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, out)?;
    Ok(())
}

/// ASCII log-log scatter: one char per point bucket ('*' RL, 'o' baseline,
/// '@' overlap). Rough but enough to eyeball Figure-3 shape in a terminal.
pub fn ascii_scatter(
    title: &str,
    xs_a: &[f64],
    ys_a: &[f64],
    xs_b: &[f64],
    ys_b: &[f64],
    w: usize,
    h: usize,
) -> String {
    let all_x: Vec<f64> = xs_a.iter().chain(xs_b).copied().filter(|v| *v > 0.0).collect();
    let all_y: Vec<f64> = ys_a.iter().chain(ys_b).copied().filter(|v| *v > 0.0).collect();
    if all_x.is_empty() || all_y.is_empty() {
        return format!("{title}: no positive data\n");
    }
    let (lx0, lx1) = minmax_log(&all_x);
    let (ly0, ly1) = minmax_log(&all_y);
    let mut grid = vec![vec![' '; w]; h];
    let mut put = |xs: &[f64], ys: &[f64], ch: char| {
        for (&x, &y) in xs.iter().zip(ys) {
            if x <= 0.0 || y <= 0.0 {
                continue;
            }
            let cx = ((x.log10() - lx0) / (lx1 - lx0 + 1e-12) * (w - 1) as f64).round() as usize;
            let cy = ((y.log10() - ly0) / (ly1 - ly0 + 1e-12) * (h - 1) as f64).round() as usize;
            let cell = &mut grid[h - 1 - cy.min(h - 1)][cx.min(w - 1)];
            *cell = if *cell == ' ' || *cell == ch { ch } else { '@' };
        }
    };
    put(xs_a, ys_a, '*');
    put(xs_b, ys_b, 'o');
    let mut out = format!("{title}  [x: 1e{lx0:.1}..1e{lx1:.1}, y: 1e{ly0:.1}..1e{ly1:.1}; '*' RL, 'o' FP64, '@' both]\n");
    for row in grid {
        out.push('|');
        out.extend(row);
        out.push_str("|\n");
    }
    out
}

fn minmax_log(xs: &[f64]) -> (f64, f64) {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &x in xs {
        let l = x.log10();
        lo = lo.min(l);
        hi = hi.max(l);
    }
    if lo == hi {
        hi = lo + 1.0;
    }
    (lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sci2_matches_paper_style() {
        assert_eq!(sci2(1.19e-14), "1.19e-14");
        assert_eq!(sci2(7.90e-17), "7.90e-17");
        assert_eq!(sci2(0.0), "0.0");
        assert_eq!(sci2(f64::NAN), "-");
    }

    #[test]
    fn table_renders_and_csvs() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(vec!["1".into(), "x,y".into()]);
        let md = t.render();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| a | b"));
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn scatter_handles_data() {
        let s = ascii_scatter("t", &[1e-8, 1e-6], &[1.0, 10.0], &[1e-7], &[2.0], 20, 5);
        assert!(s.contains('*') && s.contains('o'));
    }

    #[test]
    fn csv_writer_roundtrip() {
        let p = std::env::temp_dir().join("pa_csv_test.csv");
        write_csv(p.to_str().unwrap(), &["ep", "r"], &[&[1.0, 2.0], &[0.5, 0.6]]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.starts_with("ep,r\n1.0,0.5\n"));
    }
}
