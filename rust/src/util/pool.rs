//! Scoped-thread parallel map (rayon/tokio are unavailable offline —
//! DESIGN.md §6; on this testbed `nproc = 1`, so the pool degrades to a
//! sequential loop with zero overhead, but the implementation is a real
//! work-stealing-free chunked pool that scales on multi-core hosts).

use std::cell::Cell;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use (`PA_THREADS` overrides).
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("PA_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

thread_local! {
    /// True on pool worker threads. Nested pool calls (e.g. the
    /// row-parallel LU inside a problem-parallel `precompute`) degrade to
    /// the sequential loop instead of spawning PA_THREADS² threads — the
    /// outer, coarser level keeps every core busy, and the sequential
    /// fallback is bit-identical by the pool contract anyway.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

fn in_pool() -> bool {
    IN_POOL.with(|f| f.get())
}

/// Apply `f` to every index in `0..n`, writing results into a Vec in
/// order. Work is distributed by an atomic cursor so uneven item costs
/// (e.g. different matrix sizes) balance automatically.
///
/// A panic in `f` never aborts sibling workers mid-write: it is caught
/// on the worker, carried across the scope join, and re-raised with its
/// original payload on the calling thread — identical observable
/// behavior to the sequential path, so callers that want per-item panic
/// isolation (`Autotuner::solve_batch`) wrap `f` itself.
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = num_threads().min(n.max(1));
    if workers <= 1 || n <= 1 || in_pool() {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let cursor = AtomicUsize::new(0);
    let out_ptr = SendPtr(out.as_mut_ptr());
    let panicked: Mutex<Option<Box<dyn std::any::Any + Send>>> = Mutex::new(None);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let f = &f;
            let cursor = &cursor;
            let out_ptr = &out_ptr;
            let panicked = &panicked;
            scope.spawn(move || {
                IN_POOL.with(|flag| flag.set(true));
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    match panic::catch_unwind(AssertUnwindSafe(|| f(i))) {
                        // SAFETY: each index i is claimed exactly once via
                        // the atomic cursor; slots are disjoint; the scope
                        // outlives all writes.
                        Ok(v) => unsafe { *out_ptr.0.add(i) = Some(v) },
                        Err(payload) => {
                            let mut slot = panicked.lock().unwrap_or_else(|e| e.into_inner());
                            if slot.is_none() {
                                *slot = Some(payload);
                            }
                            break;
                        }
                    }
                }
            });
        }
    });
    if let Some(payload) = panicked.into_inner().unwrap_or_else(|e| e.into_inner()) {
        panic::resume_unwind(payload);
    }
    out.into_iter().map(|v| v.expect("slot filled")).collect()
}

struct SendPtr<T>(*mut T);
// SAFETY: used only for disjoint index writes inside a thread::scope.
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

/// Apply `f(row_index, row)` to every `row_len`-sized row of `data` in
/// place, splitting the rows across workers in contiguous bands (equal-cost
/// rows — the LU trailing update, chopped GEMV — balance statically).
///
/// Writes are row-disjoint and the arithmetic order *within* each row is
/// whatever `f` does sequentially, so results are bit-identical to the
/// plain `for` loop for any `PA_THREADS` — the invariant the chopped-LU
/// parallelization relies on (tests/kernel_bitexact.rs).
pub fn parallel_for_rows<F>(data: &mut [f64], row_len: usize, f: F)
where
    F: Fn(usize, &mut [f64]) + Sync,
{
    assert!(row_len > 0 && data.len() % row_len == 0);
    let n_rows = data.len() / row_len;
    let workers = num_threads().min(n_rows.max(1));
    if workers <= 1 || n_rows <= 1 || in_pool() {
        for (i, row) in data.chunks_exact_mut(row_len).enumerate() {
            f(i, row);
        }
        return;
    }
    let base = n_rows / workers;
    let extra = n_rows % workers;
    std::thread::scope(|scope| {
        let f = &f;
        let mut rest = data;
        let mut row0 = 0usize;
        for w in 0..workers {
            let take = base + usize::from(w < extra);
            let (band, tail) = std::mem::take(&mut rest).split_at_mut(take * row_len);
            rest = tail;
            let start = row0;
            row0 += take;
            scope.spawn(move || {
                IN_POOL.with(|flag| flag.set(true));
                for (r, row) in band.chunks_exact_mut(row_len).enumerate() {
                    f(start + r, row);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let v = parallel_map(100, |i| i * i);
        assert_eq!(v, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn empty_is_fine() {
        let v: Vec<usize> = parallel_map(0, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn uneven_workloads_complete() {
        let v = parallel_map(37, |i| {
            if i % 5 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            i + 1
        });
        assert_eq!(v.iter().sum::<usize>(), (1..=37).sum::<usize>());
    }

    #[test]
    fn respects_env_override() {
        std::env::set_var("PA_THREADS", "3");
        assert_eq!(num_threads(), 3);
        std::env::remove_var("PA_THREADS");
    }

    #[test]
    fn nested_calls_stay_correct_and_flag_resets() {
        // an inner parallel_map on a worker thread runs inline (IN_POOL
        // guard) — results must be unchanged for any thread count; no
        // env mutation here so the test cannot race siblings.
        let v = parallel_map(8, |i| {
            let inner = parallel_map(16, |j| i * 100 + j);
            inner.iter().sum::<usize>()
        });
        let want: Vec<usize> = (0..8).map(|i| (0..16).map(|j| i * 100 + j).sum()).collect();
        assert_eq!(v, want);
        // the calling thread is never flagged as a pool worker
        assert!(!super::in_pool());
    }

    #[test]
    fn worker_panic_resurfaces_with_original_payload() {
        // threaded or sequential, the caller sees the original panic
        // message (not thread::scope's generic join panic)
        let r = std::panic::catch_unwind(|| {
            parallel_map(8, |i| {
                if i == 3 {
                    panic!("boom at 3");
                }
                i
            })
        });
        let payload = r.unwrap_err();
        let msg = payload
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(msg.contains("boom at 3"), "payload was {msg:?}");
    }

    #[test]
    fn for_rows_covers_every_row_once() {
        let row_len = 7;
        for n_rows in [0usize, 1, 2, 5, 33] {
            let mut data = vec![0.0f64; n_rows * row_len];
            parallel_for_rows(&mut data, row_len, |i, row| {
                for (j, v) in row.iter_mut().enumerate() {
                    *v += (i * row_len + j) as f64 + 1.0;
                }
            });
            for (k, v) in data.iter().enumerate() {
                assert_eq!(*v, k as f64 + 1.0, "slot {k} with {n_rows} rows");
            }
        }
    }
}
