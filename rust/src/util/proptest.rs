//! Property-test harness (the proptest crate is unavailable offline —
//! DESIGN.md §6). Deterministic seeded case generation with failure
//! reporting that names the reproducing seed; a light-weight stand-in for
//! proptest's runner covering the invariant-checking style used across
//! the crate's test suites.

use crate::util::rng::Rng;

/// Run `cases` random property checks. On failure, panics with the base
/// seed + case index so the exact case replays.
pub fn check<F: FnMut(&mut Rng) -> Result<(), String>>(name: &str, seed: u64, cases: usize, mut prop: F) {
    for case in 0..cases {
        let mut rng = Rng::new(seed).fork(case as u64);
        if let Err(msg) = prop(&mut rng) {
            panic!("property {name:?} failed on seed={seed} case={case}: {msg}");
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

/// Generators used by the suites.
pub mod gen {
    use crate::util::rng::Rng;

    /// f64 covering many magnitudes plus specials.
    pub fn any_f64(rng: &mut Rng) -> f64 {
        match rng.below(20) {
            0 => 0.0,
            1 => -0.0,
            2 => f64::INFINITY,
            3 => f64::NEG_INFINITY,
            4 => f64::NAN,
            5 => f64::MIN_POSITIVE,
            6 => 5e-324,
            7 => f64::MAX,
            8 => -f64::MAX,
            _ => rng.gauss() * (rng.uniform_in(-300.0, 300.0)).exp2(),
        }
    }

    /// Finite f64 in a sane magnitude band.
    pub fn finite_f64(rng: &mut Rng) -> f64 {
        rng.gauss() * (rng.uniform_in(-30.0, 30.0)).exp2()
    }

    pub fn size(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.below(hi - lo + 1)
    }

    pub fn vec_f64(rng: &mut Rng, n: usize) -> Vec<f64> {
        (0..n).map(|_| finite_f64(rng)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_properties() {
        check("tautology", 1, 50, |rng| {
            let x = gen::finite_f64(rng);
            crate::prop_assert!(x == x, "finite f64 equals itself: {x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property \"must_fail\"")]
    fn reports_failures_with_seed() {
        check("must_fail", 2, 50, |rng| {
            let x = gen::any_f64(rng);
            crate::prop_assert!(!x.is_nan(), "hit NaN");
            Ok(())
        });
    }

    #[test]
    fn generator_covers_specials() {
        let mut seen_nan = false;
        let mut seen_inf = false;
        let mut seen_zero = false;
        for case in 0..200 {
            let mut rng = Rng::new(3).fork(case);
            let x = gen::any_f64(&mut rng);
            seen_nan |= x.is_nan();
            seen_inf |= x.is_infinite();
            seen_zero |= x == 0.0;
        }
        assert!(seen_nan && seen_inf && seen_zero);
    }
}
