//! Matrix Market (`.mtx`) loader — the interchange format of the
//! SuiteSparse collection the paper's §5.3 sparse workload models.
//!
//! Supports the common subset: `coordinate` and `array` storage, `real`
//! / `integer` / `pattern` fields, `general` / `symmetric` /
//! `skew-symmetric` symmetry. Coordinate files load as CSR
//! ([`crate::sparse::Csr`] → [`SystemInput::Sparse`], solving
//! sparse-natively through the operator path); array files load dense.
//! Complex and Hermitian files are rejected loudly.
//!
//! **Duplicate coordinate entries are rejected**, with the offending
//! line number in the error. The MM format stores each position at most
//! once (symmetric/skew files store exactly one triangle); a repeated
//! (i, j) almost always means a corrupted or hand-edited file, and the
//! two plausible recovery semantics (sum vs last-wins) silently produce
//! different matrices — so the loader refuses to guess. Mirrored
//! positions count: a symmetric file that stores both (i, j) and (j, i)
//! is rejected at the second one.
//!
//! Format reference: NIST Matrix Market, "Text File Formats".

use anyhow::{anyhow, bail, Context, Result};

use crate::linalg::Mat;
use crate::sparse::Csr;
use crate::system::SystemInput;

#[derive(Clone, Copy, PartialEq)]
enum Sym {
    General,
    Symmetric,
    Skew,
}

/// Load a `.mtx` file as a solve input (coordinate ⇒ sparse CSR, array ⇒
/// dense).
pub fn load_system(path: &str) -> Result<SystemInput> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    parse_system(&text).with_context(|| format!("parsing Matrix Market file {path}"))
}

/// Load a `.mtx` file holding a vector (n×1 or 1×n) as a dense `Vec`.
pub fn load_vector(path: &str) -> Result<Vec<f64>> {
    let sys = load_system(path)?;
    let (r, c) = (sys.n_rows(), sys.n_cols());
    if r != 1 && c != 1 {
        bail!("{path}: expected a vector (n x 1 or 1 x n), got {r} x {c}");
    }
    // row-major data of an n×1 (or 1×n) matrix is the vector itself
    Ok(match sys {
        SystemInput::Dense(m) => m.data,
        SystemInput::Sparse(s) => s.to_dense().data,
    })
}

/// Parse Matrix Market text. Exposed for in-memory use and tests; the
/// file-level entry points are [`load_system`] / [`load_vector`].
pub fn parse_system(text: &str) -> Result<SystemInput> {
    let mut lines = text.lines().enumerate();
    let header = lines.next().map(|(_, l)| l).ok_or_else(|| anyhow!("empty file"))?;
    let head: Vec<String> = header
        .split_whitespace()
        .map(|t| t.to_ascii_lowercase())
        .collect();
    if head.len() < 4 || head[0] != "%%matrixmarket" || head[1] != "matrix" {
        bail!("not a MatrixMarket matrix header: {header:?}");
    }
    let storage = head[2].as_str();
    let field = head[3].as_str();
    match field {
        "real" | "integer" | "pattern" => {}
        other => bail!("unsupported field {other:?} (supported: real, integer, pattern)"),
    }
    let sym = match head.get(4).map(|s| s.as_str()).unwrap_or("general") {
        "general" => Sym::General,
        "symmetric" => Sym::Symmetric,
        "skew-symmetric" => Sym::Skew,
        other => bail!(
            "unsupported symmetry {other:?} (supported: general, symmetric, skew-symmetric)"
        ),
    };
    // checked once the size line is parsed (below): symmetric storage
    // only makes sense for square matrices
    let require_square = |r: usize, c: usize| -> Result<()> {
        if sym != Sym::General && r != c {
            bail!("symmetric/skew-symmetric matrix must be square, got {r} x {c}");
        }
        Ok(())
    };

    // token cursor over the data lines (blank lines and % comments
    // skipped), each token tagged with its 1-based source line so
    // errors point at the file
    let mut toks = Cursor {
        toks: lines
            .filter(|(_, l)| {
                let t = l.trim();
                !t.is_empty() && !t.starts_with('%')
            })
            .flat_map(|(ln, l)| l.split_whitespace().map(move |t| (t, ln + 1)))
            .collect(),
        pos: 0,
    };

    match storage {
        "coordinate" => {
            let n_rows = toks.next_usize("row count")?;
            let n_cols = toks.next_usize("column count")?;
            require_square(n_rows, n_cols)?;
            let nnz = toks.next_usize("entry count")?;
            let pattern = field == "pattern";
            let mut triplets: Vec<(usize, usize, f64)> = Vec::with_capacity(2 * nnz);
            // every stored (and mirrored) position, for duplicate
            // rejection — see module docs for why we refuse to guess
            let mut seen = std::collections::HashSet::with_capacity(2 * nnz);
            for k in 0..nnz {
                let line = toks.peek_line();
                let i = toks.next_usize("row index")?;
                let j = toks.next_usize("column index")?;
                // pattern files carry structure only; 1.0 per stored entry
                let v = if pattern { 1.0 } else { toks.next_f64(k)? };
                if i == 0 || j == 0 || i > n_rows || j > n_cols {
                    bail!(
                        "entry {} ({i}, {j}) out of bounds for a {n_rows}x{n_cols} matrix \
                         (indices are 1-based)",
                        k + 1
                    );
                }
                if !seen.insert((i, j)) {
                    bail!(
                        "line {line}: duplicate entry ({i}, {j}) — each position may be \
                         stored once (entry {} of {nnz}; for symmetric/skew files the \
                         mirrored position counts as stored)",
                        k + 1
                    );
                }
                let (i, j) = (i - 1, j - 1);
                triplets.push((i, j, v));
                match sym {
                    Sym::General => {}
                    Sym::Symmetric => {
                        if i != j {
                            seen.insert((j + 1, i + 1));
                            triplets.push((j, i, v));
                        }
                    }
                    Sym::Skew => {
                        if i == j {
                            bail!(
                                "skew-symmetric file stores a diagonal entry ({}, {})",
                                i + 1,
                                j + 1
                            );
                        }
                        seen.insert((j + 1, i + 1));
                        triplets.push((j, i, -v));
                    }
                }
            }
            if !toks.done() {
                bail!("trailing data after {nnz} declared entries");
            }
            Ok(SystemInput::Sparse(Csr::from_triplets(n_rows, n_cols, &triplets)))
        }
        "array" => {
            if field == "pattern" {
                bail!("pattern field requires coordinate storage");
            }
            let n_rows = toks.next_usize("row count")?;
            let n_cols = toks.next_usize("column count")?;
            require_square(n_rows, n_cols)?;
            let mut m = Mat::zeros(n_rows, n_cols);
            let mut k = 0usize;
            // array storage is column-major; symmetric/skew files store
            // the lower triangle (diagonal included for symmetric only)
            for j in 0..n_cols {
                let i0 = match sym {
                    Sym::General => 0,
                    Sym::Symmetric => j,
                    Sym::Skew => j + 1,
                };
                for i in i0..n_rows {
                    let v = toks.next_f64(k)?;
                    k += 1;
                    m[(i, j)] = v;
                    match sym {
                        Sym::General => {}
                        Sym::Symmetric => m[(j, i)] = v,
                        Sym::Skew => m[(j, i)] = -v,
                    }
                }
            }
            if !toks.done() {
                bail!("trailing data after the declared {n_rows}x{n_cols} array");
            }
            Ok(SystemInput::Dense(m))
        }
        other => bail!("unsupported storage {other:?} (supported: coordinate, array)"),
    }
}

/// Token cursor over the data section; each token carries its 1-based
/// source line so truncation/parse/duplicate errors name the line.
struct Cursor<'a> {
    toks: Vec<(&'a str, usize)>,
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn bump(&mut self) -> Option<(&'a str, usize)> {
        let t = self.toks.get(self.pos).copied();
        if t.is_some() {
            self.pos += 1;
        }
        t
    }

    /// Line of the next unconsumed token (0 when exhausted).
    fn peek_line(&self) -> usize {
        self.toks.get(self.pos).map(|&(_, ln)| ln).unwrap_or(0)
    }

    fn next_usize(&mut self, what: &str) -> Result<usize> {
        let (t, line) = self
            .bump()
            .ok_or_else(|| anyhow!("unexpected end of file reading {what} (truncated?)"))?;
        t.parse::<usize>()
            .map_err(|e| anyhow!("line {line}: bad {what} {t:?}: {e}"))
    }

    fn next_f64(&mut self, k: usize) -> Result<f64> {
        let (t, line) = self
            .bump()
            .ok_or_else(|| anyhow!("unexpected end of file at value {} (truncated?)", k + 1))?;
        let v = t
            .parse::<f64>()
            .map_err(|e| anyhow!("line {line}: bad value {t:?} at value {}: {e}", k + 1))?;
        // Rust's f64 parser accepts "nan"/"inf" spellings, and any
        // out-of-range literal (1e999) overflows silently to ±inf. A
        // non-finite matrix entry poisons every downstream kernel, so
        // reject it here with the source line instead.
        if !v.is_finite() {
            bail!(
                "line {line}: non-finite value {t:?} at value {} \
                 (NaN/inf entries are not valid matrix data)",
                k + 1
            );
        }
        Ok(v)
    }

    fn done(&self) -> bool {
        self.pos == self.toks.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coordinate_general_parses_to_csr() {
        let text = "%%MatrixMarket matrix coordinate real general\n\
                    % a comment\n\
                    3 3 4\n\
                    1 1 2.0\n\
                    2 2 3.0\n\
                    3 3 4.0\n\
                    1 3 -1.5\n";
        let sys = parse_system(text).unwrap();
        let csr = sys.as_sparse().expect("coordinate loads sparse");
        assert_eq!((csr.n_rows, csr.n_cols, csr.nnz()), (3, 3, 4));
        let d = csr.to_dense();
        assert_eq!(d[(0, 0)], 2.0);
        assert_eq!(d[(0, 2)], -1.5);
        assert_eq!(d[(2, 0)], 0.0);
    }

    #[test]
    fn coordinate_symmetric_mirrors_off_diagonal() {
        let text = "%%MatrixMarket matrix coordinate real symmetric\n\
                    3 3 4\n\
                    1 1 4.0\n\
                    2 1 -1.0\n\
                    2 2 4.0\n\
                    3 3 4.0\n";
        let d = parse_system(text).unwrap().as_sparse().unwrap().to_dense();
        assert_eq!(d[(0, 1)], -1.0);
        assert_eq!(d[(1, 0)], -1.0);
        assert_eq!(d[(0, 0)], 4.0);
    }

    #[test]
    fn coordinate_skew_symmetric_negates_mirror() {
        let text = "%%MatrixMarket matrix coordinate real skew-symmetric\n\
                    2 2 1\n\
                    2 1 5.0\n";
        let d = parse_system(text).unwrap().as_sparse().unwrap().to_dense();
        assert_eq!(d[(1, 0)], 5.0);
        assert_eq!(d[(0, 1)], -5.0);
        // a stored diagonal is an error for skew files
        let bad = "%%MatrixMarket matrix coordinate real skew-symmetric\n2 2 1\n1 1 1.0\n";
        assert!(parse_system(bad).is_err());
    }

    #[test]
    fn array_general_is_column_major() {
        let text = "%%MatrixMarket matrix array real general\n\
                    2 3\n1.0\n2.0\n3.0\n4.0\n5.0\n6.0\n";
        let sys = parse_system(text).unwrap();
        let m = sys.as_dense().expect("array loads dense");
        assert_eq!(m.row(0), &[1.0, 3.0, 5.0]);
        assert_eq!(m.row(1), &[2.0, 4.0, 6.0]);
    }

    #[test]
    fn array_symmetric_fills_upper_triangle() {
        // lower triangle by columns: col 1 = [1, 2, 3], col 2 = [4, 5], col 3 = [6]
        let text = "%%MatrixMarket matrix array real symmetric\n\
                    3 3\n1.0\n2.0\n3.0\n4.0\n5.0\n6.0\n";
        let m = parse_system(text).unwrap();
        let m = m.as_dense().unwrap();
        assert_eq!(m.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(m.row(1), &[2.0, 4.0, 5.0]);
        assert_eq!(m.row(2), &[3.0, 5.0, 6.0]);
    }

    #[test]
    fn malformed_inputs_fail_loudly() {
        for bad in [
            "",
            "%%MatrixMarket tensor coordinate real general\n1 1 0\n",
            // header with too few tokens
            "%%MatrixMarket matrix\n1 1 0\n",
            "%%MatrixMarket matrix coordinate complex general\n1 1 1\n1 1 1.0 0.0\n",
            "%%MatrixMarket matrix coordinate real hermitian\n1 1 1\n1 1 1.0\n",
            "%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1.0\n",
            "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n",
            "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1 1.0\n2 2 9.9\n",
            // truncated mid-entry (row/col present, value missing)
            "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n2 2\n",
            // missing size line entirely
            "%%MatrixMarket matrix coordinate real general\n",
            "%%MatrixMarket matrix array real general\n2 2\n1.0\n2.0\n3.0\n",
            // symmetric storage on a non-square shape
            "%%MatrixMarket matrix coordinate real symmetric\n2 3 1\n1 3 5.0\n",
            "%%MatrixMarket matrix array real symmetric\n3 2\n1.0\n2.0\n3.0\n4.0\n5.0\n",
        ] {
            assert!(parse_system(bad).is_err(), "should reject: {bad:?}");
        }
    }

    #[test]
    fn truncation_errors_name_the_problem() {
        let truncated = "%%MatrixMarket matrix coordinate real general\n3 3 4\n1 1 2.0\n2 2 3.0\n";
        let err = parse_system(truncated).unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
        let bad_value = "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1 abc\n";
        let err = parse_system(bad_value).unwrap_err();
        assert!(err.to_string().contains("line 3"), "{err}");
    }

    #[test]
    fn non_finite_values_rejected_with_line_number() {
        // every spelling Rust's f64 parser would wave through: literal
        // NaN/inf tokens and out-of-range literals that overflow to inf
        for tok in ["nan", "NaN", "inf", "-inf", "Infinity", "1e999", "-1e999"] {
            let text = format!(
                "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n2 2 {tok}\n"
            );
            let err = parse_system(&text).unwrap_err();
            let msg = err.to_string();
            assert!(msg.contains("non-finite value"), "{tok}: {msg}");
            assert!(msg.contains("line 4"), "{tok}: {msg}");
            assert!(msg.contains(tok), "{tok}: {msg}");
        }
        // array storage goes through the same cursor guard
        let arr = "%%MatrixMarket matrix array real general\n2 1\n1.0\ninf\n";
        let err = parse_system(arr).unwrap_err();
        assert!(err.to_string().contains("non-finite value"), "{err}");
        // a huge-but-finite value still loads
        let ok = "%%MatrixMarket matrix coordinate real general\n1 1 1\n1 1 1e308\n";
        assert!(parse_system(ok).is_ok());
    }

    #[test]
    fn duplicate_entries_rejected_with_line_number() {
        // plain duplicate in a general file: the second (2,2) is line 5
        let dup = "%%MatrixMarket matrix coordinate real general\n\
                   3 3 3\n\
                   1 1 1.0\n\
                   2 2 2.0\n\
                   2 2 5.0\n";
        let err = parse_system(dup).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("duplicate entry (2, 2)"), "{msg}");
        assert!(msg.contains("line 5"), "{msg}");

        // comments don't shift the reported line numbers
        let dup_comments = "%%MatrixMarket matrix coordinate real general\n\
                            % a comment\n\
                            2 2 2\n\
                            1 1 1.0\n\
                            % another\n\
                            1 1 4.0\n";
        let err = parse_system(dup_comments).unwrap_err();
        assert!(err.to_string().contains("line 6"), "{err}");

        // a symmetric file storing both triangles: the mirror of (2,1)
        // already claimed (1,2), so the explicit (1,2) on line 5 dies
        let both_triangles = "%%MatrixMarket matrix coordinate real symmetric\n\
                              2 2 3\n\
                              1 1 4.0\n\
                              2 1 -1.0\n\
                              1 2 -1.0\n";
        let err = parse_system(both_triangles).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("duplicate entry (1, 2)"), "{msg}");
        assert!(msg.contains("line 5"), "{msg}");

        // pattern files get the same guard
        let dup_pattern = "%%MatrixMarket matrix coordinate pattern general\n\
                           2 2 2\n\
                           1 2\n\
                           1 2\n";
        assert!(parse_system(dup_pattern).is_err());
    }

    #[test]
    fn vector_loading_accepts_single_column() {
        let dir = std::env::temp_dir().join("pa_mtx_vec_test.mtx");
        std::fs::write(
            &dir,
            "%%MatrixMarket matrix array real general\n3 1\n1.5\n-2.5\n0.5\n",
        )
        .unwrap();
        let v = load_vector(dir.to_str().unwrap()).unwrap();
        assert_eq!(v, vec![1.5, -2.5, 0.5]);
    }

    #[test]
    fn committed_sample_loads_and_is_spd_shaped() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../testdata/sample_spd.mtx");
        let sys = load_system(path).unwrap();
        let csr = sys.as_sparse().expect("sample is coordinate ⇒ sparse");
        assert_eq!((csr.n_rows, csr.n_cols), (10, 10));
        assert_eq!(csr.nnz(), 28); // 10 diagonal + 2·9 mirrored off-diagonal
        let d = csr.to_dense();
        for i in 0..10 {
            assert_eq!(d[(i, i)], 4.0);
            for j in 0..10 {
                assert_eq!(d[(i, j)], d[(j, i)]);
            }
        }
    }
}
