//! Tiny CLI argument parser (clap is unavailable offline — DESIGN.md §6).
//!
//! Grammar: `prog <subcommand> [--key value]... [--flag]...`
//! Flags and options may appear in any order after the subcommand.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    bail!("bare '--' is not supported");
                }
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(key.to_string(), v);
                } else {
                    out.flags.push(key.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>> {
        self.get(name)
            .map(|v| {
                v.parse::<f64>()
                    .map_err(|e| anyhow!("--{name} expects a number, got {v:?}: {e}"))
            })
            .transpose()
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>> {
        self.get(name)
            .map(|v| {
                v.parse::<usize>()
                    .map_err(|e| anyhow!("--{name} expects an integer, got {v:?}: {e}"))
            })
            .transpose()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(list: &[&str]) -> Args {
        Args::parse(list.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = args(&["train", "--episodes", "100", "--out", "q.json", "--quiet"]);
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("episodes"), Some("100"));
        assert_eq!(a.get_usize("episodes").unwrap(), Some(100));
        assert_eq!(a.get("out"), Some("q.json"));
        assert!(a.flag("quiet"));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn equals_form_and_positional() {
        let a = args(&["repro", "table2", "--tau=1e-8"]);
        assert_eq!(a.subcommand.as_deref(), Some("repro"));
        assert_eq!(a.positional, vec!["table2"]);
        assert_eq!(a.get_f64("tau").unwrap(), Some(1e-8));
    }

    #[test]
    fn trailing_flag() {
        let a = args(&["x", "--fast"]);
        assert!(a.flag("fast"));
    }

    #[test]
    fn bad_number_is_error() {
        let a = args(&["x", "--tau", "abc"]);
        assert!(a.get_f64("tau").is_err());
    }

    #[test]
    fn negative_number_as_option_value() {
        // "-1.5" does not start with "--", so it is consumed as a value.
        let a = args(&["x", "--shift", "-1.5"]);
        assert_eq!(a.get_f64("shift").unwrap(), Some(-1.5));
    }
}
