//! Deterministic pseudo-random generation: xoshiro256++ seeded via
//! SplitMix64, plus Gaussian sampling (Box–Muller).
//!
//! Every experiment in the repo derives its streams from explicit seeds so
//! tables and figures are exactly reproducible run-to-run.

/// xoshiro256++ PRNG (Blackman & Vigna). Passes BigCrush; more than
/// adequate for synthetic matrix generation and ε-greedy exploration.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller variate
    gauss_spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create from a seed; distinct seeds give independent-looking streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive a child stream (for per-problem / per-episode determinism
    /// independent of iteration order).
    pub fn fork(&self, stream: u64) -> Self {
        let mut sm = self.s[0] ^ stream.wrapping_mul(0xA24B_AED4_963E_E407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style bounded rejection-free (bias < 2^-64 for our n).
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (with caching of the pair).
    pub fn gauss(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.gauss_spare = Some(r * s);
            return r * c;
        }
    }

    /// Vector of standard normals.
    pub fn gauss_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.gauss()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(2);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn fork_gives_independent_streams() {
        let base = Rng::new(9);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
        // same stream id => same sequence
        let mut c = base.fork(0);
        let mut d = base.fork(0);
        for _ in 0..10 {
            assert_eq!(c.next_u64(), d.next_u64());
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
