//! Floating-point format emulation ("chop") — the Rust mirror of the
//! Layer-1 Pallas kernel (`python/compile/kernels/chop.py`).
//!
//! Implements round-to-nearest-even quantization of f64 values onto the
//! grid of a narrower format (t significand bits, exponent range
//! [emin, emax]), exactly the semantics the paper simulates with Pychop.
//! The two implementations are cross-validated bit-for-bit via the shared
//! golden vectors in `testdata/chop_golden.json` and via the AOT
//! `chop_<fmt>` artifacts in the runtime integration tests.
//!
//! All seven formats of paper Table 1 are provided (plus the FP8 formats
//! the paper's introduction discusses). The experiment set 𝒰 of §5.1 is
//! `{BF16, TF32, FP32, FP64}` — see [`Prec`].

pub mod kernels;

pub use kernels::{
    chop_axpy, chop_block, chop_csr_matvec, chop_csr_matvec_into, chop_sub_scaled_row,
};

/// A floating-point format (paper Table 1).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Format {
    pub name: &'static str,
    /// significand bits including the implicit leading bit
    pub t: i32,
    /// exponent of the smallest positive normalized number
    pub emin: i32,
    /// exponent of the largest finite number
    pub emax: i32,
    /// largest finite value
    pub xmax: f64,
}


// Precomputed xmax values (checked against the formula in tests).
pub const BF16: Format = Format { name: "bf16", t: 8, emin: -126, emax: 127, xmax: 3.3895313892515355e38 };
pub const FP16: Format = Format { name: "fp16", t: 11, emin: -14, emax: 15, xmax: 65504.0 };
pub const TF32: Format = Format { name: "tf32", t: 11, emin: -126, emax: 127, xmax: 3.4011621342146535e38 };
pub const FP32: Format = Format { name: "fp32", t: 24, emin: -126, emax: 127, xmax: 3.4028234663852886e38 };
pub const FP64: Format = Format { name: "fp64", t: 53, emin: -1022, emax: 1023, xmax: f64::MAX };
pub const E4M3: Format = Format { name: "e4m3", t: 4, emin: -6, emax: 8, xmax: 448.0 };
pub const E5M2: Format = Format { name: "e5m2", t: 3, emin: -14, emax: 15, xmax: 57344.0 };

/// All formats of Table 1 (+FP8), keyed by name.
pub const ALL_FORMATS: [Format; 7] = [BF16, FP16, TF32, FP32, FP64, E4M3, E5M2];

pub fn format_by_name(name: &str) -> Option<Format> {
    ALL_FORMATS.iter().copied().find(|f| f.name == name)
}

/// The experiment precision set 𝒰 = {BF16, TF32, FP32, FP64} (§5.1),
/// ordered by increasing significand bits — the order relation "≤" of the
/// action-space reduction eq. (11).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Prec {
    Bf16 = 0,
    Tf32 = 1,
    Fp32 = 2,
    Fp64 = 3,
}

impl Prec {
    pub const ALL: [Prec; 4] = [Prec::Bf16, Prec::Tf32, Prec::Fp32, Prec::Fp64];

    pub fn format(self) -> &'static Format {
        match self {
            Prec::Bf16 => &BF16,
            Prec::Tf32 => &TF32,
            Prec::Fp32 => &FP32,
            Prec::Fp64 => &FP64,
        }
    }

    /// Significand bits t (used by the reward's cost model, eq. 22).
    pub fn t(self) -> i32 {
        self.format().t
    }

    /// Unit roundoff u = 2^-t (paper Table 1 column u).
    pub fn unit_roundoff(self) -> f64 {
        (-self.t() as f64).exp2()
    }

    pub fn name(self) -> &'static str {
        self.format().name
    }

    pub fn from_index(i: usize) -> Prec {
        Prec::ALL[i]
    }

    pub fn by_name(name: &str) -> Option<Prec> {
        Prec::ALL.iter().copied().find(|p| p.name() == name)
    }
}

impl std::fmt::Display for Prec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name().to_uppercase())
    }
}

/// Round `x` to `fmt` with round-to-nearest-even. Bit-identical to the
/// Pallas kernel (`chop.chop_bits`): normals round the significand to t
/// bits; values below 2^emin land on the subnormal grid; post-rounding
/// overflow gives ±inf; zero/inf/NaN pass through (signed zero kept).
///
/// Perf note (EXPERIMENTS.md §Perf): the hot path handles normal inputs
/// at/above the target's 2^emin with a branch-light sequence that
/// replaces the division by q with a multiplication by the exactly
/// representable q⁻¹ (both are powers of two, so both operations are
/// exact); zeros/specials/subnormal-landing inputs take the cold path.
#[inline]
pub fn chop(x: f64, fmt: &Format) -> f64 {
    if fmt.t == 53 {
        return x; // fp64: the carrier format, identity
    }
    let bits = x.to_bits();
    let expf = ((bits >> 52) & 0x7FF) as i32;
    // Cold path when: zero/subnormal input (expf == 0), inf/NaN
    // (expf == 0x7FF), or exponent below the target's normal range.
    // (A folded single-range compare was tried and measured no better —
    // EXPERIMENTS.md §Perf iteration log.)
    if expf == 0 || expf == 0x7FF || expf - 1023 < fmt.emin {
        return chop_cold(x, fmt, expf);
    }
    let shift = (expf - 1023) - (fmt.t - 1); // in [emin - t + 1, 1023]
    let q = f64::from_bits(((shift + 1023) as u64) << 52);
    // |shift| <= 1023 - emin + t - 1 < 1022 for every Table-1 format, so
    // 2^-shift is a normal f64 and the multiply is exact.
    let q_inv = f64::from_bits(((1023 - shift) as u64) << 52);
    let y = (x * q_inv).round_ties_even() * q;
    if y.abs() > fmt.xmax {
        if y > 0.0 { f64::INFINITY } else { f64::NEG_INFINITY }
    } else {
        y
    }
}

/// Specials, zeros, and inputs that land on the target's subnormal grid.
#[cold]
fn chop_cold(x: f64, fmt: &Format, expf: i32) -> f64 {
    if x == 0.0 || !x.is_finite() {
        // NB: Rust compares subnormals exactly (no DAZ), so `x == 0.0`
        // here is true only for genuine zeros — matching the kernel's
        // bit-level classification.
        return x;
    }
    let e = if expf == 0 { -1023 } else { expf - 1023 };
    let e_eff = e.max(fmt.emin);
    let shift = e_eff - (fmt.t - 1);
    let q = if shift >= -1022 {
        f64::from_bits(((shift + 1023) as u64) << 52)
    } else {
        // subnormal quantum of the f64 carrier (fp64-adjacent formats)
        f64::from_bits(1u64 << (shift + 1074).clamp(0, 63) as u32)
    };
    let y = (x / q).round_ties_even() * q;
    if y.abs() > fmt.xmax {
        if y > 0.0 { f64::INFINITY } else { f64::NEG_INFINITY }
    } else {
        y
    }
}

/// Chop with a [`Prec`] of the experiment set.
#[inline]
pub fn chop_p(x: f64, p: Prec) -> f64 {
    chop(x, p.format())
}

/// Chop a slice in place (vectorized: delegates to [`kernels::chop_block`],
/// bit-identical to the per-element scalar loop).
pub fn chop_slice(xs: &mut [f64], p: Prec) {
    if p == Prec::Fp64 {
        return;
    }
    kernels::chop_block(xs, p.format());
}

/// y = chop(chop(A)·chop(x)) row dot: operands in `p`, f64 accumulation,
/// result rounded — the scalar mirror of the Pallas chopped-GEMV tile
/// (callers pre-chop A and x once; see backend_native).
#[inline]
pub fn chopped_dot_prechopped(row: &[f64], x: &[f64], p: Prec) -> f64 {
    debug_assert_eq!(row.len(), x.len());
    let mut acc = 0.0;
    for i in 0..row.len() {
        acc += row[i] * x[i];
    }
    chop_p(acc, p)
}

/// Strict Pychop-style per-op rounded dot (validation mode; DESIGN.md §5
/// fidelity note).
pub fn chopped_dot_perop(row: &[f64], x: &[f64], p: Prec) -> f64 {
    let f = p.format();
    let mut acc = 0.0;
    for i in 0..row.len() {
        let prod = chop(chop(row[i], f) * chop(x[i], f), f);
        acc = chop(acc + prod, f);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xmax_constants_match_formula() {
        for f in [BF16, FP16, TF32, FP32, E5M2] {
            let want = (2.0 - (1.0 - f.t as f64).exp2()) * (f.emax as f64).exp2();
            assert_eq!(f.xmax, want, "{}", f.name);
        }
        // e4m3 reserves the top code for NaN => 448, below the formula.
        assert_eq!(E4M3.xmax, 448.0);

    }

    #[test]
    fn unit_roundoff_matches_table1() {
        // Table 1's u column (paper rounds to 3 digits).
        assert!((Prec::Bf16.unit_roundoff() - 3.91e-3).abs() < 1e-5);
        assert!((Prec::Tf32.unit_roundoff() - 4.88e-4).abs() < 1e-6);
        assert!((Prec::Fp32.unit_roundoff() - 5.96e-8).abs() < 1e-10);
        assert!((Prec::Fp64.unit_roundoff() - 1.11e-16).abs() < 1e-18);
    }

    #[test]
    fn prec_ordering_by_significand_bits() {
        assert!(Prec::Bf16 < Prec::Tf32);
        assert!(Prec::Tf32 < Prec::Fp32);
        assert!(Prec::Fp32 < Prec::Fp64);
        assert!(Prec::Bf16.t() < Prec::Tf32.t());
    }

    #[test]
    fn basic_values() {
        // bf16: spacing at 1.0 is 2^-7
        assert_eq!(chop(1.0, &BF16), 1.0);
        assert_eq!(chop(1.0 + 2f64.powi(-8), &BF16), 1.0); // tie -> even
        assert_eq!(chop(1.0 + 2f64.powi(-7), &BF16), 1.0 + 2f64.powi(-7));
        assert_eq!(chop(1.0 + 3.0 * 2f64.powi(-8), &BF16), 1.0 + 2.0 * 2f64.powi(-7));
        // fp16 overflow
        assert_eq!(chop(65504.0, &FP16), 65504.0);
        assert!(chop(65520.0, &FP16).is_infinite());
        // fp64 identity incl. subnormals
        assert_eq!(chop(5e-324, &FP64), 5e-324);
    }

    #[test]
    fn specials_pass_through() {
        for f in &ALL_FORMATS {
            assert_eq!(chop(0.0, f), 0.0);
            assert!(chop(-0.0, f).is_sign_negative());
            assert_eq!(chop(f64::INFINITY, f), f64::INFINITY);
            assert_eq!(chop(f64::NEG_INFINITY, f), f64::NEG_INFINITY);
            assert!(chop(f64::NAN, f).is_nan());
        }
    }

    #[test]
    fn subnormal_targets() {
        // fp16 subnormal grid: quantum 2^(-14-10) = 2^-24
        let q = 2f64.powi(-24);
        assert_eq!(chop(1.49 * q, &FP16), q);
        assert_eq!(chop(0.49 * q, &FP16), 0.0);
        assert_eq!(chop(0.5 * q, &FP16), 0.0); // tie -> even (0)
        assert_eq!(chop(1.5 * q, &FP16), 2.0 * q); // tie -> even (2q)
    }

    #[test]
    fn golden_vectors_cross_language() {
        // Shared ground truth with the Python oracle/kernel.
        // single cross-language copy at the repo root (python/tests reads
        // the same file)
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../testdata/chop_golden.json");
        let text = std::fs::read_to_string(path).expect("golden vectors present");
        let v = crate::util::json::parse(&text).unwrap();
        let mut n = 0;
        for case in v.get("cases").unwrap().as_arr().unwrap() {
            let x = f64::from_bits(u64::from_le_bytes(
                hex_to_bytes(case.get("x").unwrap().as_str().unwrap()).try_into().unwrap(),
            ));
            for (fname, want_hex) in case.get("out").unwrap().as_obj().unwrap() {
                let fmt = format_by_name(fname).unwrap();
                let want = f64::from_bits(u64::from_le_bytes(
                    hex_to_bytes(want_hex.as_str().unwrap()).try_into().unwrap(),
                ));
                let got = chop(x, &fmt);
                assert!(
                    got.to_bits() == want.to_bits() || (got.is_nan() && want.is_nan()),
                    "chop({x:e}, {fname}) = {got:e}, want {want:e}"
                );
                n += 1;
            }
        }
        assert!(n > 2000, "golden coverage: {n}");
    }

    fn hex_to_bytes(s: &str) -> Vec<u8> {
        (0..s.len())
            .step_by(2)
            .map(|i| u8::from_str_radix(&s[i..i + 2], 16).unwrap())
            .collect()
    }

    #[test]
    fn property_idempotent_and_monotone_and_bounded() {
        use crate::util::proptest::{check, gen};
        check("chop_invariants", 0xC0FFEE, 2000, |rng| {
            let x = gen::any_f64(rng);
            for f in &ALL_FORMATS {
                let y = chop(x, f);
                let yy = chop(y, f);
                crate::prop_assert!(
                    y.to_bits() == yy.to_bits() || (y.is_nan() && yy.is_nan()),
                    "idempotence: chop({x:e},{}) = {y:e} then {yy:e}", f.name
                );
                if x.is_finite() && y.is_finite() && x != 0.0 && x.abs() >= (f.emin as f64).exp2() {
                    let rel = ((y - x) / x).abs();
                    crate::prop_assert!(
                        rel <= (-f.t as f64).exp2(),
                        "rel err {rel:e} > u for {} at {x:e}", f.name
                    );
                }
            }
            // monotone
            let a = gen::finite_f64(rng);
            let b = gen::finite_f64(rng);
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            for f in &ALL_FORMATS {
                crate::prop_assert!(
                    chop(lo, f) <= chop(hi, f),
                    "monotone violated for {}", f.name
                );
            }
            Ok(())
        });
    }

    #[test]
    fn perop_dot_stays_near_accum_dot() {
        use crate::util::proptest::{check, gen};
        check("dot_modes", 7, 200, |rng| {
            let n = gen::size(rng, 1, 32);
            let row: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
            let x: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
            for p in [Prec::Bf16, Prec::Tf32, Prec::Fp32] {
                let mut rc = row.clone();
                let mut xc = x.clone();
                chop_slice(&mut rc, p);
                chop_slice(&mut xc, p);
                let fast = chopped_dot_prechopped(&rc, &xc, p);
                let strict = chopped_dot_perop(&row, &x, p);
                let scale: f64 = row.iter().zip(&x).map(|(a, b)| (a * b).abs()).sum::<f64>() + 1e-30;
                let gap = (fast - strict).abs();
                crate::prop_assert!(
                    gap <= 4.0 * n as f64 * p.unit_roundoff() * scale,
                    "modes diverge: {gap:e} at n={n} p={p}"
                );
            }
            Ok(())
        });
    }
}
