//! Branch-free slice kernels for the chop emulation (DESIGN.md §Perf).
//!
//! The scalar [`chop`](super::chop) takes a hot/cold branch per element;
//! on contiguous data that branch defeats auto-vectorization. The kernels
//! here classify through exponent-field arithmetic only — every lane runs
//! the same instruction sequence (two selects, no calls), so LLVM turns
//! the inner loops into SIMD.
//!
//! **Semantics contract** (regression-tested in `tests/kernel_bitexact.rs`
//! against the golden vectors and the scalar reference): for every eligible
//! format the kernels are bit-identical to per-element `chop()`, including
//! signed zeros, subnormal inputs, overflow-to-±inf, and NaN passthrough.
//!
//! Eligibility: the branch-free path builds the quantum q = 2^shift *and*
//! its reciprocal directly from exponent bits, which requires both to be
//! normal f64 for every possible input exponent: `3 ≤ t < 53` and
//! `emin - t + 1 ≥ -1022`. All Table-1 (+FP8) formats qualify; a format
//! outside that envelope falls back to the scalar loop, so the kernels are
//! total over arbitrary [`Format`]s.

use super::{chop, Format};

/// Can `fmt` take the branch-free path? (See module docs for the bound.)
#[inline]
pub fn branchless_ok(fmt: &Format) -> bool {
    fmt.t >= 3 && fmt.t < 53 && fmt.emin - (fmt.t - 1) >= -1022
}

/// One element of the branch-free sequence. Mirrors the Pallas kernel
/// (`chop.chop_bits`) shape: clamp the exponent, build q and q⁻¹ from
/// bits (both exact powers of two, so scale/unscale are exact), round
/// ties-to-even, saturate past xmax to ±inf. Zeros, subnormals, ±inf and
/// NaN all fall out of the arithmetic without a dedicated branch.
#[inline(always)]
fn chop_one(x: f64, t: i32, emin: i32, xmax: f64) -> f64 {
    let bits = x.to_bits();
    let expf = ((bits >> 52) & 0x7FF) as i32;
    // f64-subnormal inputs (expf == 0) are below 2^emin for every eligible
    // format: clamping their exponent to emin lands them on the target's
    // subnormal grid, same as the scalar cold path.
    let e = if expf == 0 { -1023 } else { expf - 1023 };
    let e_eff = if e < emin { emin } else { e };
    let shift = e_eff - (t - 1); // in [emin - t + 1, 1025 - t] ⊂ [-1022, 1022]
    let q = f64::from_bits(((shift + 1023) as u64) << 52);
    let q_inv = f64::from_bits(((1023 - shift) as u64) << 52);
    let y = (x * q_inv).round_ties_even() * q;
    if y.abs() > xmax {
        f64::INFINITY.copysign(y)
    } else {
        y
    }
}

/// Chop a contiguous block in place — the vectorized equivalent of
/// `for x in xs { *x = chop(*x, fmt) }`.
pub fn chop_block(xs: &mut [f64], fmt: &Format) {
    if fmt.t == 53 {
        return; // carrier format: identity
    }
    if !branchless_ok(fmt) {
        for x in xs.iter_mut() {
            *x = chop(*x, fmt);
        }
        return;
    }
    let (t, emin, xmax) = (fmt.t, fmt.emin, fmt.xmax);
    for x in xs.iter_mut() {
        *x = chop_one(*x, t, emin, xmax);
    }
}

/// Fused `y[i] = chop(y[i] + chop(alpha * x[i]))` — the per-op-rounded
/// axpy. For fp64 this degenerates to a plain (exact) axpy.
pub fn chop_axpy(y: &mut [f64], alpha: f64, x: &[f64], fmt: &Format) {
    debug_assert_eq!(y.len(), x.len());
    if fmt.t == 53 {
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += alpha * xi;
        }
        return;
    }
    if !branchless_ok(fmt) {
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi = chop(*yi + chop(alpha * xi, fmt), fmt);
        }
        return;
    }
    let (t, emin, xmax) = (fmt.t, fmt.emin, fmt.xmax);
    for (yi, xi) in y.iter_mut().zip(x) {
        let p = chop_one(alpha * xi, t, emin, xmax);
        *yi = chop_one(*yi + p, t, emin, xmax);
    }
}

/// Fused `y[i] = chop(y[i] - chop(m * u[i]))` — the rank-1 Schur-update
/// row step of `lu_factor_chopped` (mirror of `pallas_outer_update`),
/// one kernel call per row instead of 2·n scalar `chop()` calls.
/// For fp64 this is the plain right-looking update `y -= m·u`.
pub fn chop_sub_scaled_row(y: &mut [f64], m: f64, u: &[f64], fmt: &Format) {
    debug_assert_eq!(y.len(), u.len());
    if fmt.t == 53 {
        for (yi, ui) in y.iter_mut().zip(u) {
            *yi -= m * ui;
        }
        return;
    }
    if !branchless_ok(fmt) {
        for (yi, ui) in y.iter_mut().zip(u) {
            *yi = chop(*yi - chop(m * ui, fmt), fmt);
        }
        return;
    }
    let (t, emin, xmax) = (fmt.t, fmt.emin, fmt.xmax);
    for (yi, ui) in y.iter_mut().zip(u) {
        let p = chop_one(m * ui, t, emin, xmax);
        *yi = chop_one(*yi - p, t, emin, xmax);
    }
}

/// One CSR row dot, f64 accumulation over the stored entries only.
#[inline(always)]
fn csr_row_dot(col_idx: &[usize], values: &[f64], x: &[f64]) -> f64 {
    debug_assert_eq!(col_idx.len(), values.len());
    let mut acc = 0.0;
    for (j, v) in col_idx.iter().zip(values) {
        acc += v * x[*j];
    }
    acc
}

/// Chopped CSR matvec: `values` and `x` pre-chopped to `fmt`, f64 row
/// accumulation, one branch-free rounding per output element — the
/// sparse mirror of `chopped_matvec_prechopped` on the chopped dense
/// form, and **bit-identical** to it for finite `x`: the structural
/// zeros the dense loop visits contribute exactly-`+0.0` products, and a
/// running f64 sum that starts at `+0.0` can never be `-0.0` under
/// round-to-nearest, so skipping them cannot change a single bit
/// (property-locked in `sparse::tests` across all [`super::Prec`]s).
///
/// The kernel itself assumes finite `x` — a ±inf operand would multiply
/// the *skipped* zeros into NaN on the dense side. The caller
/// (`Csr::chopped_matvec_prechopped`) screens for that and poisons the
/// result, matching the dense path's deterministic failure.
pub fn chop_csr_matvec(
    row_ptr: &[usize],
    col_idx: &[usize],
    values: &[f64],
    x: &[f64],
    fmt: &Format,
) -> Vec<f64> {
    let mut out = Vec::new();
    chop_csr_matvec_into(row_ptr, col_idx, values, x, fmt, &mut out);
    out
}

/// In-place form of [`chop_csr_matvec`]: writes into `out` (cleared +
/// refilled — allocation-free once `out` has capacity `n_rows`). Same
/// per-element computation on every branch, so bit-identical to the
/// allocating form.
pub fn chop_csr_matvec_into(
    row_ptr: &[usize],
    col_idx: &[usize],
    values: &[f64],
    x: &[f64],
    fmt: &Format,
    out: &mut Vec<f64>,
) {
    let n_rows = row_ptr.len().saturating_sub(1);
    let row = |i: usize| {
        let (s, e) = (row_ptr[i], row_ptr[i + 1]);
        csr_row_dot(&col_idx[s..e], &values[s..e], x)
    };
    out.clear();
    if fmt.t == 53 {
        out.extend((0..n_rows).map(row)); // carrier format: no rounding
        return;
    }
    if !branchless_ok(fmt) {
        out.extend((0..n_rows).map(|i| chop(row(i), fmt)));
        return;
    }
    let (t, emin, xmax) = (fmt.t, fmt.emin, fmt.xmax);
    out.extend((0..n_rows).map(|i| chop_one(row(i), t, emin, xmax)));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chop::{chop_p, Prec, ALL_FORMATS};

    #[test]
    fn all_table1_formats_take_the_fast_path() {
        for f in &ALL_FORMATS {
            if f.t == 53 {
                continue;
            }
            assert!(branchless_ok(f), "{}", f.name);
        }
        // an fp64-adjacent hypothetical format must fall back
        let odd = Format { name: "t50", t: 50, emin: -1022, emax: 1023, xmax: f64::MAX };
        assert!(!branchless_ok(&odd));
    }

    #[test]
    fn block_matches_scalar_on_edge_classes() {
        let cases = [
            0.0,
            -0.0,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::NAN,
            5e-324,
            -5e-324,
            1e-310,
            f64::MIN_POSITIVE,
            f64::MAX,
            -f64::MAX,
            1.0,
            1.0 + 2f64.powi(-8),
            1.0 + 2f64.powi(-7),
            65504.0,
            65520.0,
            3.39e38,
            -1.0e-40,
        ];
        for f in &ALL_FORMATS {
            let mut buf = cases.to_vec();
            chop_block(&mut buf, f);
            for (i, (&got, &x)) in buf.iter().zip(&cases).enumerate() {
                let want = chop(x, f);
                assert!(
                    got.to_bits() == want.to_bits() || (got.is_nan() && want.is_nan()),
                    "{}[{i}]: chop_block({x:e}) = {got:e}, scalar {want:e}",
                    f.name
                );
            }
        }
    }

    #[test]
    fn block_matches_scalar_property() {
        use crate::util::proptest::{check, gen};
        check("chop_block_bitexact", 0xB10C, 2000, |rng| {
            let x = gen::any_f64(rng);
            for f in &ALL_FORMATS {
                let mut buf = [x];
                chop_block(&mut buf, f);
                let want = chop(x, f);
                crate::prop_assert!(
                    buf[0].to_bits() == want.to_bits() || (buf[0].is_nan() && want.is_nan()),
                    "chop_block({x:e}, {}) = {:e}, scalar {want:e}",
                    f.name,
                    buf[0]
                );
            }
            Ok(())
        });
    }

    #[test]
    fn fused_kernels_match_scalar_composition() {
        use crate::util::proptest::{check, gen};
        check("fused_bitexact", 0xF05E, 500, |rng| {
            let n = gen::size(rng, 1, 40);
            let y0: Vec<f64> = (0..n).map(|_| gen::finite_f64(rng)).collect();
            let u: Vec<f64> = (0..n).map(|_| gen::finite_f64(rng)).collect();
            let m = gen::finite_f64(rng);
            for f in &ALL_FORMATS {
                let mut fast = y0.clone();
                chop_sub_scaled_row(&mut fast, m, &u, f);
                let mut fast_a = y0.clone();
                chop_axpy(&mut fast_a, m, &u, f);
                for j in 0..n {
                    let want_s = chop(y0[j] - chop(m * u[j], f), f);
                    let want_a = chop(y0[j] + chop(m * u[j], f), f);
                    crate::prop_assert!(
                        fast[j].to_bits() == want_s.to_bits()
                            || (fast[j].is_nan() && want_s.is_nan()),
                        "sub_scaled {} j={j}: {:e} vs {want_s:e}",
                        f.name,
                        fast[j]
                    );
                    crate::prop_assert!(
                        fast_a[j].to_bits() == want_a.to_bits()
                            || (fast_a[j].is_nan() && want_a.is_nan()),
                        "axpy {} j={j}: {:e} vs {want_a:e}",
                        f.name,
                        fast_a[j]
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn csr_matvec_kernel_matches_scalar_composition() {
        // 2x3 matrix [[1.5, 0, -2.25], [0, 3.5, 0]] in CSR
        let row_ptr = [0usize, 2, 3];
        let col_idx = [0usize, 2, 1];
        let values = [1.5, -2.25, 3.5];
        let x = [2.0, -1.0, 4.0];
        for f in &ALL_FORMATS {
            let got = chop_csr_matvec(&row_ptr, &col_idx, &values, &x, f);
            let want = [
                chop(1.5 * 2.0 + -2.25 * 4.0, f),
                chop(3.5 * -1.0, f),
            ];
            assert_eq!(got.len(), 2);
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.to_bits(), w.to_bits(), "{}", f.name);
            }
        }
        // empty matrix: no rows, no output
        assert!(chop_csr_matvec(&[0], &[], &[], &[], &crate::chop::BF16).is_empty());
    }

    #[test]
    fn fp64_kernels_are_exact_updates() {
        let mut y = vec![1.0, 2.0, 3.0];
        chop_sub_scaled_row(&mut y, 2.0, &[0.5, 0.5, 0.5], Prec::Fp64.format());
        assert_eq!(y, vec![0.0, 1.0, 2.0]);
        chop_axpy(&mut y, 2.0, &[0.5, 0.5, 0.5], Prec::Fp64.format());
        assert_eq!(y, vec![1.0, 2.0, 3.0]);
        let x = chop_p(1.0 + 2f64.powi(-60), Prec::Fp64);
        assert_eq!(x, 1.0 + 2f64.powi(-60));
    }
}
