//! Tabular action-value estimator Q : S_d × A → ℝ (§3.2).
//!
//! One flat table over (state, action) with the incremental update of
//! eq. (6)/(27): Q ← Q + α (R − Q). Supports the fixed-α schedule the
//! paper uses in §5 (α = 0.5) and the 1/N(s,a) visit-count schedule of
//! Alg. 1 line 13. Persists to JSON together with its action list so a
//! trained policy is self-describing. Since policy schema v3 each
//! serialized action is a 7-tuple
//! `[family, u_f, u, u_g, u_r, precond, restart_m]` — the solver family
//! rides in front of the four precisions, and the v3 hyperparameters
//! (preconditioner name, GMRES restart length) trail them. v2 5-tuples
//! and pre-v2 4-tuples are rejected with layout-specific messages.

use anyhow::{bail, Result};

use crate::bandit::action::{Action, ActionSpace, Precond, SolverFamily};
use crate::chop::Prec;
use crate::util::json::{self, Value};

#[derive(Clone, Debug)]
pub struct QTable {
    pub n_states: usize,
    pub space: ActionSpace,
    /// Q values, row-major [state][action]
    q: Vec<f64>,
    /// visit counts N(s_d, a)
    visits: Vec<u32>,
}

impl QTable {
    pub fn new(n_states: usize, space: ActionSpace) -> QTable {
        let n = n_states * space.len();
        QTable { n_states, space, q: vec![0.0; n], visits: vec![0; n] }
    }

    #[inline]
    fn idx(&self, state: usize, action: usize) -> usize {
        debug_assert!(state < self.n_states && action < self.space.len());
        state * self.space.len() + action
    }

    #[inline]
    pub fn q(&self, state: usize, action: usize) -> f64 {
        self.q[self.idx(state, action)]
    }

    #[inline]
    pub fn visits(&self, state: usize, action: usize) -> u32 {
        self.visits[self.idx(state, action)]
    }

    pub fn total_visits(&self, state: usize) -> u64 {
        let base = state * self.space.len();
        self.visits[base..base + self.space.len()]
            .iter()
            .map(|&v| v as u64)
            .sum()
    }

    /// Incremental update (eq. 6 / 27). `alpha = 0` selects the 1/N(s,a)
    /// schedule of Alg. 1. Returns the reward-prediction error R − Q
    /// *before* the update (the RPE traced in the appendix figures).
    ///
    /// Non-finite rewards are **rejected**, not absorbed: a single
    /// NaN/inf reward (e.g. a NaN nbe from a failed solve leaking past a
    /// caller's guard) would otherwise write NaN into the table, where
    /// it poisons `argmax`/`visited_ranked` forever. The cell is left
    /// untouched — no visit is counted — and the returned RPE is 0.0.
    /// Callers that need to surface the drop count it themselves (see
    /// `OnlineLearner::skipped_nonfinite`).
    pub fn update(&mut self, state: usize, action: usize, r: f64, alpha: f64) -> f64 {
        if !r.is_finite() {
            return 0.0;
        }
        let i = self.idx(state, action);
        self.visits[i] += 1;
        let a = if alpha > 0.0 { alpha } else { 1.0 / self.visits[i] as f64 };
        let rpe = r - self.q[i];
        self.q[i] += a * rpe;
        rpe
    }

    /// Greedy action (eq. 7); deterministic tie-break toward the lowest
    /// index, which the cost-ordered action list makes "cheapest wins".
    pub fn argmax(&self, state: usize) -> usize {
        let base = state * self.space.len();
        let row = &self.q[base..base + self.space.len()];
        let mut best = 0;
        for (i, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = i;
            }
        }
        best
    }

    pub fn best_action(&self, state: usize) -> Action {
        self.space.actions[self.argmax(state)]
    }

    /// Greedy argmax restricted to *visited* actions — the inference-time
    /// policy. Zero-initialized Q is "optimism in the face of
    /// uncertainty": correct for training-time exploration, but at
    /// inference an action the agent never tried must not beat actions
    /// with measured (possibly negative) value. Returns None when the
    /// state was never visited at all (caller falls back to FP64).
    pub fn argmax_visited(&self, state: usize) -> Option<usize> {
        let base = state * self.space.len();
        let mut best: Option<usize> = None;
        for i in 0..self.space.len() {
            if self.visits[base + i] > 0 {
                match best {
                    None => best = Some(i),
                    Some(b) if self.q[base + i] > self.q[base + b] => best = Some(i),
                    _ => {}
                }
            }
        }
        best
    }

    /// Inference-time action (greedy over visited; FP64 when unvisited).
    pub fn best_action_visited(&self, state: usize) -> Action {
        match self.argmax_visited(state) {
            Some(i) => self.space.actions[i],
            None => Action::FP64,
        }
    }

    /// All *visited* actions of a state, best-Q first (stable sort, so
    /// equal Q ties break toward the lower = cheaper index, matching
    /// [`QTable::argmax_visited`]). This is the serving facade's
    /// degradation ladder: rung 1 is `[0]`, rung 2 the next entry, etc.
    /// Empty when the state was never visited.
    pub fn visited_ranked(&self, state: usize) -> Vec<usize> {
        let base = state * self.space.len();
        let mut ranked: Vec<usize> =
            (0..self.space.len()).filter(|&i| self.visits[base + i] > 0).collect();
        // total_cmp, not partial_cmp-or-Equal: a NaN cell (impossible
        // since update() guards, but cheap to defend against) gets a
        // deterministic total order instead of making the comparator
        // inconsistent and scrambling the whole ladder.
        ranked.sort_by(|&a, &b| self.q[base + b].total_cmp(&self.q[base + a]));
        ranked
    }

    /// Max Q over a state's row.
    pub fn max_q(&self, state: usize) -> f64 {
        self.q(state, self.argmax(state))
    }

    // ---- snapshot API (serve::snapshot / serve::online) ----

    /// Total observations absorbed across every (state, action) cell —
    /// the online learner's progress counter; the serving daemon embeds
    /// it in snapshot stats so operators can see how much live traffic a
    /// policy version has learned from.
    pub fn total_observations(&self) -> u64 {
        self.visits.iter().map(|&v| v as u64).sum()
    }

    /// Order-sensitive FNV-1a fingerprint of the full table contents
    /// (shape, Q bits, visit counts). Two tables fingerprint equal iff
    /// they are byte-identical under [`QTable::to_json`] — the cheap
    /// equality the online-replay determinism tests and the snapshot
    /// dedup check hinge on. (`-0.0` and `0.0` hash differently; the
    /// update rule never produces `-0.0` from `0.0` starts.)
    pub fn fingerprint(&self) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = FNV_OFFSET;
        let mut absorb = |x: u64| {
            for b in x.to_le_bytes() {
                h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
            }
        };
        absorb(self.n_states as u64);
        absorb(self.space.len() as u64);
        for &q in &self.q {
            absorb(q.to_bits());
        }
        for &v in &self.visits {
            absorb(v as u64);
        }
        h
    }

    // ---- persistence ----

    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("n_states", json::num(self.n_states as f64)),
            (
                "actions",
                Value::Arr(
                    self.space
                        .actions
                        .iter()
                        .map(|a| {
                            let mut parts = vec![json::s(a.solver.name())];
                            parts.extend(a.tuple().iter().map(|p| json::s(p.name())));
                            parts.push(json::s(a.precond.name()));
                            parts.push(json::num(a.restart_m as f64));
                            Value::Arr(parts)
                        })
                        .collect(),
                ),
            ),
            ("q", json::num_arr(&self.q)),
            (
                "visits",
                Value::Arr(self.visits.iter().map(|&v| json::num(v as f64)).collect()),
            ),
        ])
    }

    pub fn from_json(v: &Value) -> Result<QTable> {
        let n_states = v.get("n_states")?.as_usize()?;
        let mut actions = Vec::new();
        for a in v.get("actions")?.as_arr()? {
            let parts = a.as_arr()?;
            match parts.len() {
                7 => {}
                4 => bail!(
                    "action tuple must have 7 entries \
                     [family, u_f, u, u_g, u_r, precond, restart_m], got 4 \
                     (pre-v2 precision-only layout?)"
                ),
                5 => bail!(
                    "action tuple must have 7 entries \
                     [family, u_f, u, u_g, u_r, precond, restart_m], got 5 \
                     (v2 layout — predates the preconditioner/restart dimensions?)"
                ),
                n => bail!(
                    "action tuple must have 7 entries \
                     [family, u_f, u, u_g, u_r, precond, restart_m], got {n}"
                ),
            }
            let fam_name = parts[0].as_str()?;
            let solver = SolverFamily::by_name(fam_name)
                .ok_or_else(|| anyhow::anyhow!("unknown solver family {fam_name:?}"))?;
            let p: Vec<Prec> = parts[1..5]
                .iter()
                .map(|x| {
                    Prec::by_name(x.as_str()?)
                        .ok_or_else(|| anyhow::anyhow!("unknown precision {:?}", x))
                })
                .collect::<Result<_>>()?;
            let pc_name = parts[5].as_str()?;
            let precond = Precond::by_name(pc_name)
                .ok_or_else(|| anyhow::anyhow!("unknown preconditioner {pc_name:?}"))?;
            let raw_m = parts[6].as_f64()?;
            if !raw_m.is_finite() || raw_m < 0.0 || raw_m.fract() != 0.0 || raw_m > 4096.0 {
                bail!("restart_m is not a valid restart length ({raw_m}): corrupt policy file");
            }
            actions.push(Action {
                solver,
                u_f: p[0],
                u: p[1],
                u_g: p[2],
                u_r: p[3],
                precond,
                restart_m: raw_m as usize,
            });
        }
        let space = ActionSpace { actions };
        let q: Vec<f64> = v
            .get("q")?
            .as_arr()?
            .iter()
            .enumerate()
            .map(|(i, x)| {
                let qv = x.as_f64()?;
                if !qv.is_finite() {
                    bail!("q[{i}] is not finite ({qv}): corrupt or truncated policy file");
                }
                Ok(qv)
            })
            .collect::<Result<_>>()?;
        let visits: Vec<u32> = v
            .get("visits")?
            .as_arr()?
            .iter()
            .enumerate()
            .map(|(i, x)| {
                let raw = x.as_f64()?;
                if !raw.is_finite() || raw < 0.0 || raw.fract() != 0.0 || raw > u32::MAX as f64 {
                    bail!("visits[{i}] is not a valid count ({raw}): corrupt policy file");
                }
                Ok(raw as u32)
            })
            .collect::<Result<_>>()?;
        if q.len() != n_states * space.len() || visits.len() != q.len() {
            bail!(
                "Q-table shape mismatch: {} states x {} actions vs {} values",
                n_states,
                space.len(),
                q.len()
            );
        }
        Ok(QTable { n_states, space, q, visits })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> QTable {
        QTable::new(4, ActionSpace::reduced())
    }

    #[test]
    fn update_moves_toward_reward() {
        let mut t = table();
        let rpe = t.update(0, 3, 10.0, 0.5);
        assert_eq!(rpe, 10.0);
        assert_eq!(t.q(0, 3), 5.0);
        let rpe2 = t.update(0, 3, 10.0, 0.5);
        assert_eq!(rpe2, 5.0);
        assert_eq!(t.q(0, 3), 7.5);
        assert_eq!(t.visits(0, 3), 2);
    }

    #[test]
    fn one_over_n_schedule_computes_running_mean() {
        let mut t = table();
        for (i, r) in [2.0, 4.0, 6.0, 8.0].iter().enumerate() {
            t.update(1, 0, *r, 0.0);
            assert_eq!(t.visits(1, 0), (i + 1) as u32);
        }
        assert!((t.q(1, 0) - 5.0).abs() < 1e-12); // mean of 2,4,6,8
    }

    #[test]
    fn argmax_and_tie_break() {
        let mut t = table();
        assert_eq!(t.argmax(2), 0); // all-zero row -> first (cheapest)
        t.update(2, 7, 3.0, 1.0);
        t.update(2, 11, 3.0, 1.0);
        assert_eq!(t.argmax(2), 7); // tie -> lower index
        t.update(2, 11, 3.0, 1.0); // nudges 11 above via repeated reward? no: alpha=1 sets exactly 3.0
        assert_eq!(t.argmax(2), 7);
        t.update(2, 11, 4.0, 1.0);
        assert_eq!(t.argmax(2), 11);
    }

    #[test]
    fn rows_are_independent() {
        let mut t = table();
        t.update(0, 0, 9.0, 1.0);
        assert_eq!(t.q(1, 0), 0.0);
        assert_eq!(t.total_visits(0), 1);
        assert_eq!(t.total_visits(1), 0);
    }

    #[test]
    fn json_roundtrip_exact() {
        let mut t = table();
        t.update(0, 1, 0.1 + 0.2, 0.5);
        t.update(3, 34, -7.25, 0.0);
        let text = t.to_json().to_string();
        let back = QTable::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.n_states, t.n_states);
        assert_eq!(back.space.actions, t.space.actions);
        for s in 0..4 {
            for a in 0..35 {
                assert_eq!(back.q(s, a), t.q(s, a));
                assert_eq!(back.visits(s, a), t.visits(s, a));
            }
        }
    }

    #[test]
    fn json_roundtrip_preserves_solver_family() {
        // grown space: the serialized 7-tuples must carry the family and
        // the v3 hyperparameters
        let mut t = QTable::new(2, ActionSpace::extended_precond_top_k(9));
        t.update(1, t.space.len() - 1, 3.5, 1.0); // a restart arm
        let text = t.to_json().to_string();
        assert!(text.contains("\"cg-ir\""), "family missing from JSON: {text}");
        assert!(text.contains("\"lu-ir\""));
        assert!(text.contains("\"ssor\""), "precond missing from JSON: {text}");
        assert!(text.contains("\"block-jacobi\""));
        let back = QTable::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.space.actions, t.space.actions);
        assert_eq!(back.q(1, t.space.len() - 1), 3.5);
        // a tuple stripped to the bare precisions (pre-v2) is rejected
        // with the pre-v2 hint
        let legacy4 = text.replacen("[\"lu-ir\",", "[", 1).replacen(",\"none\",0.0]", "]", 1);
        assert_ne!(legacy4, text);
        let err = QTable::from_json(&crate::util::json::parse(&legacy4).unwrap()).unwrap_err();
        assert!(err.to_string().contains("got 4"), "{err}");
        assert!(err.to_string().contains("pre-v2"), "{err}");
        // a 5-tuple (v2) action is rejected with the v2 hint
        let legacy5 = text.replacen(",\"none\",0.0]", "]", 1);
        assert_ne!(legacy5, text);
        let err = QTable::from_json(&crate::util::json::parse(&legacy5).unwrap()).unwrap_err();
        assert!(err.to_string().contains("got 5"), "{err}");
        assert!(err.to_string().contains("v2 layout"), "{err}");
        // an unknown family name is rejected loudly
        let bad = text.replacen("\"cg-ir\"", "\"qr-ir\"", 1);
        assert_ne!(bad, text);
        let err = QTable::from_json(&crate::util::json::parse(&bad).unwrap()).unwrap_err();
        assert!(err.to_string().contains("unknown solver family"), "{err}");
        // an unknown preconditioner name is rejected loudly
        let bad = text.replacen("\"ssor\"", "\"ilu0\"", 1);
        assert_ne!(bad, text);
        let err = QTable::from_json(&crate::util::json::parse(&bad).unwrap()).unwrap_err();
        assert!(err.to_string().contains("unknown preconditioner"), "{err}");
        // a fractional restart length is rejected, not truncated
        let bad = text.replacen("\"none\",0.0]", "\"none\",0.5]", 1);
        assert_ne!(bad, text);
        let err = QTable::from_json(&crate::util::json::parse(&bad).unwrap()).unwrap_err();
        assert!(err.to_string().contains("valid restart length"), "{err}");
    }

    #[test]
    fn visited_ranked_orders_by_q_and_skips_unvisited() {
        let mut t = table();
        assert!(t.visited_ranked(0).is_empty()); // never visited
        t.update(0, 4, 1.0, 1.0);
        t.update(0, 9, 5.0, 1.0);
        t.update(0, 2, -3.0, 1.0);
        t.update(0, 6, 1.0, 1.0); // tie with action 4 -> lower index first
        assert_eq!(t.visited_ranked(0), vec![9, 4, 6, 2]);
        assert_eq!(t.visited_ranked(0)[0], t.argmax_visited(0).unwrap());
        assert!(t.visited_ranked(1).is_empty()); // rows independent
    }

    #[test]
    fn from_json_rejects_non_finite_q_and_bad_visits() {
        let mut t = table();
        t.update(0, 1, 2.5, 1.0);
        let text = t.to_json().to_string();
        // a raw out-of-range literal parses to +inf in our reader — the
        // exact shape of a hand-edited/corrupt policy file
        let bad_q = text.replacen("2.5", "1e999", 1);
        assert_ne!(bad_q, text);
        let err = QTable::from_json(&crate::util::json::parse(&bad_q).unwrap()).unwrap_err();
        assert!(err.to_string().contains("not finite"), "{err}");
        // fractional / negative visit counts are rejected, not truncated
        for bad in ["1.5", "-1"] {
            let bad_v =
                text.replacen("\"visits\":[0.0,1.0,", &format!("\"visits\":[0.0,{bad},"), 1);
            assert_ne!(bad_v, text, "fixture must contain the visits prefix");
            let err = QTable::from_json(&crate::util::json::parse(&bad_v).unwrap()).unwrap_err();
            assert!(err.to_string().contains("valid count"), "{err}");
        }
    }

    #[test]
    fn non_finite_reward_cannot_poison_argmax_or_ladder() {
        // regression: a NaN/inf reward used to write NaN into the table,
        // after which partial_cmp-based ranking scrambled the
        // degradation ladder. The update is now skipped entirely.
        let mut t = table();
        t.update(0, 4, 1.0, 1.0);
        t.update(0, 9, 5.0, 1.0);
        t.update(0, 2, -3.0, 1.0);
        let before_fp = t.fingerprint();
        let before_ranked = t.visited_ranked(0);
        let before_argmax = t.argmax(0);
        for poison in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            // poison both an already-visited cell and a fresh one
            assert_eq!(t.update(0, 9, poison, 1.0), 0.0);
            assert_eq!(t.update(0, 7, poison, 0.0), 0.0);
        }
        // no cell moved, no visit counted, ordering identical
        assert_eq!(t.fingerprint(), before_fp);
        assert_eq!(t.visited_ranked(0), before_ranked);
        assert_eq!(t.argmax(0), before_argmax);
        assert_eq!(t.visits(0, 7), 0, "poisoned cell must stay unvisited");
        assert_eq!(t.total_observations(), 3);
        // and the table still accepts good rewards afterwards
        t.update(0, 9, 6.0, 1.0);
        assert_eq!(t.q(0, 9), 6.0);
    }

    #[test]
    fn fingerprint_and_observation_counter_track_content() {
        let mut a = table();
        let mut b = table();
        assert_eq!(a.fingerprint(), b.fingerprint());
        assert_eq!(a.total_observations(), 0);
        a.update(0, 1, 2.0, 0.5);
        assert_ne!(a.fingerprint(), b.fingerprint());
        assert_eq!(a.total_observations(), 1);
        b.update(0, 1, 2.0, 0.5);
        assert_eq!(a.fingerprint(), b.fingerprint(), "same updates, same bits");
        // same Q value via a different visit history -> different print
        let mut c = table();
        c.update(0, 1, 2.0, 0.5);
        c.update(0, 1, 1.0, 1.0);
        c.update(0, 1, 1.0, 1.0);
        assert_eq!(c.q(0, 1), a.q(0, 1));
        assert_ne!(c.fingerprint(), a.fingerprint());
        assert_eq!(c.total_observations(), 3);
    }

    #[test]
    fn from_json_rejects_shape_mismatch() {
        let t = table();
        let mut v = t.to_json();
        if let Value::Obj(m) = &mut v {
            m.insert("n_states".into(), json::num(5.0));
        }
        assert!(QTable::from_json(&v).is_err());
    }

    #[test]
    fn property_q_stays_bounded_by_reward_range() {
        use crate::util::proptest::{check, gen as g};
        check("q_bounded", 17, 100, |rng| {
            let mut t = QTable::new(2, ActionSpace::reduced());
            let (lo, hi) = (-10.0, 25.0);
            for _ in 0..200 {
                let s = rng.below(2);
                let a = rng.below(35);
                let r = rng.uniform_in(lo, hi);
                let alpha = if rng.uniform() < 0.5 { 0.0 } else { rng.uniform_in(0.01, 1.0) };
                t.update(s, a, r, alpha);
            }
            for s in 0..2 {
                for a in 0..35 {
                    let q = t.q(s, a);
                    crate::prop_assert!(
                        (lo..=hi).contains(&q) || q == 0.0,
                        "Q out of reward hull: {q}"
                    );
                }
            }
            let _ = g::size(rng, 1, 2);
            Ok(())
        });
    }
}
