//! The paper's contribution: a contextual bandit for precision selection
//! (§3, Alg. 1) instantiated for GMRES-IR (§4, Alg. 3).
//!
//! * [`action`] — the joint action space 𝒜 = 𝒜₁⁴ and its monotone
//!   reduction (eq. 11–12): 256 → 35 configurations — extended with the
//!   solver-family dimension (LU/GMRES-IR vs CG-IR; DESIGN.md §2d).
//! * [`reward`] — the multi-objective reward (eq. 21–25).
//! * [`qtable`] — tabular action-value estimator Q(s_d, a) with the
//!   incremental update (eq. 6/27) and both learning-rate schedules.
//! * [`policy`] — ε-greedy selection (eq. 5) with linear decay (eq. 13).
//! * [`trainer`] — the training loop of Alg. 3 with the deterministic
//!   solve cache, reward/RPE episode traces (Figs. 5–12), and the
//!   inference-time greedy policy.

pub mod action;
pub mod policy;
pub mod qtable;
pub mod reward;
pub mod trainer;

pub use action::{Action, ActionSpace, Precond, SolverFamily};
pub use policy::{epsilon_at, select_action};
pub use qtable::QTable;
pub use reward::{reward, RewardInputs};
pub use trainer::{EpisodeTrace, SolveCache, TrainedPolicy, Trainer};
