//! ε-greedy policy (eq. 5) with the paper's linear decay schedule
//! (eq. 13 / 26): ε_t = max(ε_min, 1 − t/T).

use crate::bandit::qtable::QTable;
use crate::util::rng::Rng;

/// Exploration rate at (0-based) episode t of T (eq. 13).
pub fn epsilon_at(episode: usize, total_episodes: usize, eps_min: f64) -> f64 {
    let t = episode as f64;
    let cap = total_episodes.max(1) as f64;
    (1.0 - t / cap).max(eps_min)
}

/// Alg. 1 line 10 / Alg. 3 line 10: with probability ε a uniformly random
/// action from 𝒜_reduced, otherwise the greedy argmax. Returns the action
/// index and whether the step explored.
pub fn select_action(q: &QTable, state: usize, eps: f64, rng: &mut Rng) -> (usize, bool) {
    if rng.uniform() < eps {
        (rng.below(q.space.len()), true)
    } else {
        (q.argmax(state), false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bandit::action::ActionSpace;

    #[test]
    fn epsilon_schedule_matches_eq13() {
        assert_eq!(epsilon_at(0, 100, 0.05), 1.0);
        assert_eq!(epsilon_at(50, 100, 0.05), 0.5);
        assert_eq!(epsilon_at(99, 100, 0.05), 0.05f64.max(1.0 - 0.99));
        assert_eq!(epsilon_at(100, 100, 0.05), 0.05);
        assert_eq!(epsilon_at(1000, 100, 0.05), 0.05);
    }

    #[test]
    fn greedy_when_eps_zero() {
        let mut q = QTable::new(1, ActionSpace::reduced());
        q.update(0, 20, 5.0, 1.0);
        let mut rng = Rng::new(0);
        for _ in 0..50 {
            let (a, explored) = select_action(&q, 0, 0.0, &mut rng);
            assert_eq!(a, 20);
            assert!(!explored);
        }
    }

    #[test]
    fn uniform_when_eps_one() {
        let q = QTable::new(1, ActionSpace::reduced());
        let mut rng = Rng::new(1);
        let mut counts = vec![0usize; q.space.len()];
        for _ in 0..3500 {
            let (a, explored) = select_action(&q, 0, 1.0, &mut rng);
            assert!(explored);
            counts[a] += 1;
        }
        // every action visited, roughly uniformly (expected 100 each)
        assert!(counts.iter().all(|&c| c > 40), "{counts:?}");
    }

    #[test]
    fn exploration_fraction_tracks_eps() {
        let mut q = QTable::new(1, ActionSpace::reduced());
        q.update(0, 3, 1.0, 1.0);
        let mut rng = Rng::new(2);
        let eps = 0.3;
        let n = 20_000;
        let explored = (0..n)
            .filter(|_| select_action(&q, 0, eps, &mut rng).1)
            .count();
        let frac = explored as f64 / n as f64;
        assert!((frac - eps).abs() < 0.02, "frac {frac}");
    }
}
