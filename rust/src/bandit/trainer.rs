//! Training loop (Alg. 3) and the trained-policy artifact.
//!
//! The environment is deterministic per (system, action) — the solver has
//! no stochastic component — so solve outcomes are memoized. Unique work
//! is bounded by N_train × |𝒜_reduced| (≤ 3500 at paper scale) instead of
//! T × N_train (10⁴); everything else is O(1) lookups. This is the key
//! L3 optimization that makes paper-scale training tractable on one core
//! (EXPERIMENTS.md §Perf).
//!
//! With the stateless-session backend API the exhaustive precompute is
//! additionally **parallel across problems** (`PA_THREADS` workers):
//! each worker owns a private [`ProblemSession`] and a private per-u_f
//! factor memo, outcomes are keyed by (problem, action), and every solve
//! is deterministic — so the cache contents are bit-identical for any
//! thread count (regression-locked by `tests/api_parallel.rs`).

use std::collections::HashMap;

use anyhow::{bail, Context as _, Result};

use crate::bandit::action::{Action, ActionSpace, SolverFamily};
use crate::bandit::policy::{epsilon_at, select_action};
use crate::bandit::qtable::QTable;
use crate::bandit::reward::{reward, RewardInputs};
use crate::chop::Prec;
use crate::features::{phi_kappa_of, phi_norm_of, Context, Discretizer};
use crate::gen::Problem;
use crate::solver::family::solve_refinement;
use crate::solver::ir::{gmres_ir_prefactored, solve_per_step_ws, SolveOutcome};
use crate::solver::workspace::SolveWorkspace;
use crate::solver::{LuHandle, ProblemSession, SolverBackend};
use crate::util::config::Config;
use crate::util::json::{self, Value};
use crate::util::pool::parallel_map;
use crate::util::rng::Rng;

/// Per-episode training telemetry (appendix Figures 5–12: total reward
/// and mean |RPE| per episode).
#[derive(Clone, Debug, Default)]
pub struct EpisodeTrace {
    pub episode: Vec<f64>,
    pub mean_reward: Vec<f64>,
    pub mean_abs_rpe: Vec<f64>,
    pub epsilon: Vec<f64>,
    pub explored_frac: Vec<f64>,
}

/// Outcome signature kept in the solve cache (x itself is not needed for
/// training — only the reward inputs).
#[derive(Clone, Copy, Debug)]
pub struct CachedOutcome {
    pub ferr: f64,
    pub nbe: f64,
    pub outer_iters: usize,
    pub gmres_iters: usize,
    pub failed: bool,
}

impl CachedOutcome {
    fn of(out: &SolveOutcome) -> CachedOutcome {
        CachedOutcome {
            ferr: out.ferr,
            nbe: out.nbe,
            outer_iters: out.outer_iters,
            gmres_iters: out.gmres_iters,
            failed: out.failed,
        }
    }
}

/// Memoized solve outcomes keyed by (problem index, action index).
///
/// Rewards depend on the weight setting but *outcomes* do not, so one
/// cache serves both W1 and W2 training runs at the same τ — the
/// coordinator exploits this to halve the dominant cost of a table run.
#[derive(Default)]
pub struct SolveCache {
    map: HashMap<(usize, usize), CachedOutcome>,
    /// LU memo for the non-precomputed fallback path, keyed by (problem
    /// index, u_f index); `None` records a breakdown. Factors recur
    /// across episodes (ε-greedy visits each problem once per episode,
    /// in problem-major order), so the memo must span problems to ever
    /// hit. Worst-case retention is N·4 `Arc`'d factor matrices while a
    /// large-action-space training is in flight; [`Trainer::train`]
    /// releases it when the episode loop finishes.
    factor_memo: HashMap<(usize, usize), Option<LuHandle>>,
    pub hits: u64,
    pub misses: u64,
}

impl SolveCache {
    pub fn new() -> SolveCache {
        SolveCache::default()
    }

    pub fn unique_solves(&self) -> usize {
        self.map.len()
    }

    /// The memoized outcome for `(pi, ai)`, if already computed.
    pub fn cached(&self, pi: usize, ai: usize) -> Option<CachedOutcome> {
        self.map.get(&(pi, ai)).copied()
    }

    /// Get or compute the outcome of solving `problems[pi]` with `action`.
    ///
    /// The compute path shares one LU factorization per (problem, u_f)
    /// through the `factor_memo`, instead of re-factoring A on every
    /// action (the seed version called plain `gmres_ir`, which re-ran the
    /// O(n³) factorization per action). Unlike `precompute`, the chopped
    /// copies of A are *not* shared across actions — each miss opens a
    /// fresh session, an accepted O(n²) cost on this fallback path
    /// (sessions borrow the problem and cannot outlive one call here).
    pub fn outcome(
        &mut self,
        backend: &dyn SolverBackend,
        problems: &[Problem],
        pi: usize,
        action: &crate::bandit::action::Action,
        ai: usize,
        cfg: &Config,
    ) -> Result<CachedOutcome> {
        if let Some(o) = self.map.get(&(pi, ai)) {
            self.hits += 1;
            return Ok(*o);
        }
        self.misses += 1;
        let p = &problems[pi];
        let session = ProblemSession::new(&p.system);
        let out = if action.solver == SolverFamily::CgIr {
            // factorization-free family: nothing to memoize besides the
            // outcome itself
            solve_refinement(backend, &session, p, action, cfg, None)?
        } else {
            let fi = action.u_f as usize;
            let slot = self
                .factor_memo
                .entry((pi, fi))
                .or_insert_with(|| backend.lu_factor(&session, action.u_f).ok());
            match slot.as_ref() {
                Some(f) => gmres_ir_prefactored(backend, &session, p, action, cfg, Some(f))?,
                None => SolveOutcome::failure(p.n),
            }
        };
        let c = CachedOutcome::of(&out);
        self.map.insert((pi, ai), c);
        Ok(c)
    }

    /// Release the LU factor memo (outcomes stay). Called when a training
    /// run finishes; factors are only useful while (problem, action)
    /// pairs are still being discovered.
    pub fn release_factors(&mut self) {
        self.factor_memo.clear();
    }

    /// Exhaustive per-problem precompute (§Perf): with the reduced action
    /// space (k_top = 9), ε-greedy training ends up visiting nearly every
    /// (problem, action) pair anyway, so computing them problem-by-problem
    /// costs the same number of solves while letting every action with the
    /// same u_f share one LU factorization (9 actions / 4 factorizations)
    /// and the session reuse its chopped-A copies across actions.
    ///
    /// Problems are distributed over `PA_THREADS` workers. Outcomes are
    /// keyed by (pi, ai) and each solve is deterministic, so the resulting
    /// cache is bit-identical for any thread count.
    pub fn precompute(
        &mut self,
        backend: &dyn SolverBackend,
        problems: &[Problem],
        space: &ActionSpace,
        cfg: &Config,
    ) -> Result<()> {
        // Snapshot the missing (problem, action-list) pairs first so the
        // workers never touch `self`.
        let todo: Vec<(usize, Vec<usize>)> = (0..problems.len())
            .filter_map(|pi| {
                let ais: Vec<usize> = (0..space.len())
                    .filter(|&ai| !self.map.contains_key(&(pi, ai)))
                    .collect();
                if ais.is_empty() { None } else { Some((pi, ais)) }
            })
            .collect();
        if todo.is_empty() {
            return Ok(());
        }
        let computed: Vec<Result<Vec<((usize, usize), CachedOutcome)>>> =
            parallel_map(todo.len(), |k| {
                let (pi, ais) = &todo[k];
                let p = &problems[*pi];
                let session = ProblemSession::new(&p.system);
                // Factor once per u_f actually used by the space.
                let mut factors: [Option<Option<LuHandle>>; 4] = [None, None, None, None];
                let mut out = Vec::with_capacity(ais.len());
                for &ai in ais {
                    let action = &space.actions[ai];
                    let o = if action.solver == SolverFamily::CgIr {
                        // factorization-free family: straight dispatch
                        // (the session still shares its chopped copies
                        // across the CG actions of this problem)
                        solve_refinement(backend, &session, p, action, cfg, None)?
                    } else {
                        let fi = action.u_f as usize;
                        if factors[fi].is_none() {
                            factors[fi] =
                                Some(backend.lu_factor(&session, Prec::from_index(fi)).ok());
                        }
                        match factors[fi].as_ref().unwrap() {
                            Some(f) => {
                                gmres_ir_prefactored(backend, &session, p, action, cfg, Some(f))?
                            }
                            // factorization breakdown: same failure outcome
                            // gmres_ir would produce
                            None => SolveOutcome::failure(p.n),
                        }
                    };
                    out.push(((*pi, ai), CachedOutcome::of(&o)));
                }
                Ok(out)
            });
        for worker in computed {
            for (key, o) in worker? {
                self.misses += 1;
                self.map.insert(key, o);
            }
        }
        Ok(())
    }
}

/// Version of the policy-JSON schema written by [`TrainedPolicy::save`].
/// Bump whenever the serialized layout or its semantics change; loading
/// rejects any other version loudly instead of misreading the file.
///
/// * v1 — 4-tuple actions (precisions only; pre-solver-family)
/// * v2 — 5-tuple actions `[family, u_f, u, u_g, u_r]`; the
///   `action_space_hash` covers the family dimension
/// * v3 — 7-tuple actions `[family, u_f, u, u_g, u_r, precond,
///   restart_m]` (DESIGN.md §2i), a required decay axis in the
///   discretizer, and an `action_space_hash` that absorbs the two new
///   dimensions
pub const POLICY_SCHEMA_VERSION: usize = 3;

/// Order-sensitive FNV-1a over the action list (each action as its
/// solver family, its four precision indices, its preconditioner code,
/// and its restart length). A policy JSON carries this hash so a policy
/// trained against one action space can never be silently applied to
/// another (e.g. after a `k_top` change reorders the reduced list, a
/// family-swapped list with identical precision tuples, or a
/// precond/restart variant of an otherwise-identical arm).
pub fn action_space_hash(space: &ActionSpace) -> u64 {
    const FNV_OFFSET: u64 = 0xcbf29ce484222325;
    const FNV_PRIME: u64 = 0x100000001b3;
    let mut h = FNV_OFFSET;
    for a in &space.actions {
        // family byte offset past the precision codes so (family, prec)
        // streams can never collide
        h = (h ^ (a.solver as u64 + 0x10)).wrapping_mul(FNV_PRIME);
        for p in a.tuple() {
            h = (h ^ (p as u64 + 1)).wrapping_mul(FNV_PRIME);
        }
        // v3 dimensions in their own byte ranges (0x20+, 0x40+): legacy
        // arms hash to *different* values than their v2 stream — the
        // version gate rejects cross-version loads before the hash is
        // ever compared, so no collision pressure across versions.
        h = (h ^ (a.precond as u64 + 0x20)).wrapping_mul(FNV_PRIME);
        h = (h ^ (a.restart_m as u64 + 0x40)).wrapping_mul(FNV_PRIME);
    }
    h
}

/// Whether `a` is a legal per-step successor of `cur`: identical
/// solve-level shape (family, u_f, preconditioner, restart length —
/// those are fixed once the trajectory starts) and escalation-only
/// working precisions. Mirrors `solver::ir::clamp_step_action`, so an
/// action passing this filter survives the clamp unchanged.
fn step_candidate(a: &Action, cur: &Action) -> bool {
    a.solver == cur.solver
        && a.u_f == cur.u_f
        && a.precond == cur.precond
        && a.restart_m == cur.restart_m
        && a.u >= cur.u
        && a.u_g >= cur.u_g
        && a.u_r >= cur.u_r
}

/// The trained artifact: Q-table + the discretizer it was fitted with,
/// persisted as versioned JSON (`schema_version`, `action_space_hash`).
#[derive(Clone, Debug)]
pub struct TrainedPolicy {
    pub qtable: QTable,
    pub discretizer: Discretizer,
}

impl TrainedPolicy {
    /// Greedy inference (Alg. 1 line 18 / Alg. 3 line 23), restricted to
    /// actions the agent actually tried in this state; unvisited states
    /// fall back to the safe all-FP64 configuration.
    pub fn select(&self, p: &Problem) -> crate::bandit::action::Action {
        self.select_features(p.kappa_est, p.norm_inf)
    }

    /// [`TrainedPolicy::select`] from raw (κ₁ estimate, ‖A‖∞) features —
    /// the serving path, where the cached session carries the features
    /// without a [`Problem`] wrapper. Same context mapping as
    /// `features::context_of` (via the shared `phi_*_of` helpers — this
    /// used to inline `kappa_est.max(δ_c)`, whose NaN-eating `max`
    /// silently routed unknown-κ requests to the *easiest* κ bin), so
    /// the two entries are bit-identical.
    pub fn select_features(&self, kappa_est: f64, norm_inf: f64) -> Action {
        let c = Context {
            phi_kappa: phi_kappa_of(kappa_est, self.discretizer.delta_c),
            phi_norm: phi_norm_of(norm_inf, self.discretizer.delta_n),
            phi_decay: f64::NAN,
        };
        self.qtable.best_action_visited(self.discretizer.state_of_context(c))
    }

    /// All visited actions for the state these features map to, best-Q
    /// first (same context mapping as [`TrainedPolicy::select_features`],
    /// whose pick is always entry 0 when non-empty). The serving facade
    /// walks this list as its graceful-degradation ladder when the greedy
    /// pick fails under fault injection.
    pub fn select_features_ranked(&self, kappa_est: f64, norm_inf: f64) -> Vec<Action> {
        let c = Context {
            phi_kappa: phi_kappa_of(kappa_est, self.discretizer.delta_c),
            phi_norm: phi_norm_of(norm_inf, self.discretizer.delta_n),
            phi_decay: f64::NAN,
        };
        self.qtable
            .visited_ranked(self.discretizer.state_of_context(c))
            .into_iter()
            .map(|i| self.qtable.space.actions[i])
            .collect()
    }

    /// Per-step (MDP) inference: the greedy action for the *current* IR
    /// step, given the running residual-decay feature φ₃ and the arm the
    /// trajectory is already on. Only **visited** escalation candidates
    /// of `current` (same solver/u_f/precond/restart_m, working
    /// precisions ⩾ current — the same set `clamp_step_action` would
    /// admit) are considered; an unvisited state keeps the current arm,
    /// so a per-step policy can never de-escalate or jump shapes
    /// mid-trajectory. Used as the `decide` hook of
    /// [`crate::solver::ir::solve_per_step_ws`].
    pub fn decide_step(
        &self,
        kappa_est: f64,
        norm_inf: f64,
        phi_decay: f64,
        current: &Action,
    ) -> Action {
        let c = Context {
            phi_kappa: phi_kappa_of(kappa_est, self.discretizer.delta_c),
            phi_norm: phi_norm_of(norm_inf, self.discretizer.delta_n),
            phi_decay,
        };
        let s = self.discretizer.state_of_context(c);
        let mut best: Option<usize> = None;
        for (ai, a) in self.qtable.space.actions.iter().enumerate() {
            if step_candidate(a, current) && self.qtable.visits(s, ai) > 0 {
                let better = match best {
                    Some(b) => self.qtable.q(s, ai) > self.qtable.q(s, b),
                    None => true,
                };
                if better {
                    best = Some(ai);
                }
            }
        }
        best.map_or(*current, |ai| self.qtable.space.actions[ai])
    }

    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("schema_version", json::num(POLICY_SCHEMA_VERSION as f64)),
            (
                "action_space_hash",
                json::s(&format!("{:016x}", action_space_hash(&self.qtable.space))),
            ),
            ("qtable", self.qtable.to_json()),
            ("discretizer", self.discretizer.to_json()),
        ])
    }

    /// Parse a policy, rejecting loudly on any schema mismatch: a missing
    /// or unsupported `schema_version`, an `action_space_hash` that does
    /// not match the action list actually stored, or a Q-table whose
    /// state count disagrees with the discretizer.
    pub fn from_json(v: &Value) -> Result<TrainedPolicy> {
        let ver = v
            .get("schema_version")
            .context(
                "policy JSON has no schema_version — not a policy artifact of this \
                 crate (or a pre-versioning file; retrain with the current binary)",
            )?
            .as_usize()?;
        if ver != POLICY_SCHEMA_VERSION {
            // version-specific hints: the two legacy layouts are common
            // enough on disk that "unsupported" alone sends people
            // diffing JSON by hand
            let hint = match ver {
                1 => "v1 predates the solver-family action encoding",
                2 => "v2 predates the preconditioner/restart/per-step action dimensions",
                _ => "not a version this crate has ever written",
            };
            bail!(
                "unsupported policy schema_version {ver} (this build reads version \
                 {POLICY_SCHEMA_VERSION}; {hint}); retrain the policy or use a \
                 matching binary"
            );
        }
        let qtable = QTable::from_json(v.get("qtable")?)?;
        let stored = v.get("action_space_hash")?.as_str()?.to_string();
        let actual = format!("{:016x}", action_space_hash(&qtable.space));
        if stored != actual {
            bail!(
                "policy action-space hash mismatch: file declares {stored} but its \
                 action list hashes to {actual} — the policy was trained for a \
                 different action space (k_top / ordering change?)"
            );
        }
        let discretizer = Discretizer::from_json(v.get("discretizer")?)?;
        if qtable.n_states != discretizer.n_states() {
            bail!(
                "policy shape mismatch: Q-table has {} states but the discretizer \
                 defines {} ({}x{}x{} bins)",
                qtable.n_states,
                discretizer.n_states(),
                discretizer.kappa.n_bins,
                discretizer.norm.n_bins,
                discretizer.decay.n_bins
            );
        }
        Ok(TrainedPolicy { qtable, discretizer })
    }

    /// Persist the policy atomically (tmp+rename via [`crate::util::fsx`])
    /// so a crash mid-write can never leave a truncated JSON that
    /// [`TrainedPolicy::from_json`] rejects on the next load.
    pub fn save(&self, path: &str) -> Result<()> {
        crate::util::fsx::atomic_write_str(path, &self.to_json().to_string())
    }

    pub fn load(path: &str) -> Result<TrainedPolicy> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        TrainedPolicy::from_json(&json::parse(&text)?)
            .with_context(|| format!("loading policy {path}"))
    }
}

/// Alg.-3 trainer. Borrows a [`SolveCache`] so multiple trainings (e.g.
/// W1 and W2 at the same τ) share solve outcomes.
///
/// The action space routes on the dataset (DESIGN.md §2d): an all-SPD
/// training set (`Problem::spd`, e.g. `gen::sparse_dataset`) trains over
/// the two-family **extended** space — CG-IR is only meaningful on SPD
/// systems, and the context features carry no SPD bit the policy could
/// condition on, so mixed datasets stay LU-only.
pub struct Trainer<'a> {
    pub cfg: &'a Config,
    /// The action space of the **last** `train` call (dataset-derived:
    /// recomputed via [`Trainer::space_for`] at the start of every
    /// `train`, clobbering whatever was here). Read it *after* training
    /// — e.g. a dense run reports 10 actions, an SPD run 20. Setting it
    /// by hand has no effect; use `cfg.families` to pin the routing.
    pub space: ActionSpace,
    pub cache: &'a mut SolveCache,
}

impl<'a> Trainer<'a> {
    pub fn new(cfg: &'a Config, cache: &'a mut SolveCache) -> Trainer<'a> {
        Trainer {
            cfg,
            space: ActionSpace::reduced_top_k(cfg.k_top),
            cache,
        }
    }

    /// The action space `train` will use for this dataset: extended
    /// (both families) iff every problem is SPD and `cfg.families` is
    /// "auto". `families = "lu-only"` pins the paper's LU-only space
    /// everywhere (the §5.3 repro tables use this for fidelity).
    ///
    /// `cfg.precond_arms` additionally grows the extended route with the
    /// v3 preconditioner/restart arms
    /// ([`ActionSpace::extended_precond_top_k`]). It is opt-in and only
    /// meaningful on the SPD route — the grown CG arms need SPD systems,
    /// and keeping the default space byte-stable preserves every
    /// existing policy's `action_space_hash`.
    pub fn space_for(cfg: &Config, problems: &[Problem]) -> ActionSpace {
        let all_spd = !problems.is_empty() && problems.iter().all(|p| p.spd);
        if all_spd && cfg.families != "lu-only" {
            if cfg.precond_arms {
                ActionSpace::extended_precond_top_k(cfg.k_top)
            } else {
                ActionSpace::extended_top_k(cfg.k_top)
            }
        } else {
            ActionSpace::reduced_top_k(cfg.k_top)
        }
    }

    /// Train on `problems` for `cfg.episodes` episodes (Alg. 3 lines
    /// 5–22). Returns the policy and the per-episode trace.
    ///
    /// The dominant cost — the exhaustive (problem, action) solve sweep —
    /// runs parallel across problems; the ε-greedy episode loop itself is
    /// serial (it is pure cache lookups + Q updates) so the RNG draw
    /// sequence, and therefore the result, is independent of `PA_THREADS`.
    pub fn train(
        &mut self,
        backend: &dyn SolverBackend,
        problems: &[Problem],
        quiet: bool,
    ) -> Result<(TrainedPolicy, EpisodeTrace)> {
        let cfg = self.cfg;
        // dataset-routed action space: both families on all-SPD sets
        self.space = Trainer::space_for(cfg, problems);
        let disc = Discretizer::fit(
            problems,
            cfg.bins_kappa,
            cfg.bins_norm,
            cfg.delta_c,
            cfg.delta_n,
        );
        let mut q = QTable::new(disc.n_states(), self.space.clone());
        let mut rng = Rng::new(cfg.seed ^ 0xE715_0DE5);
        let mut trace = EpisodeTrace::default();

        // §Perf: exhaustive per-problem precompute with LU sharing when
        // the action space is small enough that training would visit
        // (almost) everything anyway. The cap doubles for the extended
        // space (2 families × (k_top=9 ⇒ 10) actions) and only then —
        // LU-only datasets keep the historical threshold, so raising it
        // for CG cannot flip an existing LU-only config from
        // incremental training to a full N×|𝒜| sweep.
        // The precond-grown space (extended + 8) gets its own threshold
        // for the same reason the extended one did: raising the cap only
        // when the grown arms are actually present can never flip an
        // existing configuration from incremental training to a sweep.
        let precompute_cap = if self.space.actions.iter().any(|a| !a.is_legacy_shape()) {
            32
        } else if self.space.has_family(SolverFamily::CgIr) {
            24
        } else {
            12
        };
        if self.space.len() <= precompute_cap {
            let space = self.space.clone();
            self.cache.precompute(backend, problems, &space, cfg)?;
        }

        // Precompute states (features are solve-independent).
        let states: Vec<usize> = problems.iter().map(|p| disc.state_of(p)).collect();

        for t in 0..cfg.episodes {
            let eps = epsilon_at(t, cfg.episodes, cfg.eps_min);
            let mut sum_r = 0.0;
            let mut sum_rpe = 0.0;
            let mut explored_n = 0usize;
            for (pi, p) in problems.iter().enumerate() {
                let s = states[pi];
                let (ai, explored) = select_action(&q, s, eps, &mut rng);
                explored_n += explored as usize;
                let action = self.space.actions[ai];
                let o = self
                    .cache
                    .outcome(backend, problems, pi, &action, ai, cfg)?;
                let r = reward(
                    cfg,
                    &self.space.actions[ai],
                    &RewardInputs {
                        ferr: o.ferr,
                        nbe: o.nbe,
                        gmres_iters: o.gmres_iters,
                        kappa: p.kappa_est,
                        failed: o.failed,
                    },
                );
                let rpe = q.update(s, ai, r, cfg.alpha);
                sum_r += r;
                sum_rpe += rpe.abs();
            }
            let n = problems.len() as f64;
            trace.episode.push(t as f64);
            trace.mean_reward.push(sum_r / n);
            trace.mean_abs_rpe.push(sum_rpe / n);
            trace.epsilon.push(eps);
            trace.explored_frac.push(explored_n as f64 / n);
            if !quiet && (t + 1) % 10 == 0 {
                eprintln!(
                    "  episode {:>3}/{}: eps={:.2} mean_reward={:+.3} mean|RPE|={:.3} cache {}/{}",
                    t + 1,
                    cfg.episodes,
                    eps,
                    sum_r / n,
                    sum_rpe / n,
                    self.cache.hits,
                    self.cache.hits + self.cache.misses
                );
            }
        }
        // factors only help while pairs are being discovered; outcomes
        // stay memoized for the next training (e.g. W2 after W1).
        self.cache.release_factors();
        Ok((TrainedPolicy { qtable: q, discretizer: disc }, trace))
    }

    /// Per-step (MDP) training — DESIGN.md §2i, enabled by
    /// `cfg.per_step`. The discretizer gains `cfg.bins_decay` bins on
    /// the residual-decay axis and each episode runs **rollouts**
    /// through [`solve_per_step_ws`]: the initial arm is ε-greedy at the
    /// problem's static state (φ₃ = NaN), then before every later IR
    /// iteration the decide hook re-selects ε-greedily among the visited
    /// arm's escalation candidates at the (φ₁, φ₂, φ₃-bin) state. Every
    /// (state, arm) the trajectory touched receives a Monte-Carlo update
    /// toward the rollout's terminal reward (evaluated per arm, so each
    /// step pays its own precision cost).
    ///
    /// Outcomes depend on the whole decision trajectory, not a single
    /// arm, so the [`SolveCache`] cannot memoize them — the episode loop
    /// re-solves every rollout. It is deliberately **serial**: no
    /// `parallel_map`, one RNG draw sequence, so the trained table is
    /// byte-identical for every `PA_THREADS` (locked by
    /// `tests/solver_family.rs`).
    pub fn train_per_step(
        &mut self,
        backend: &dyn SolverBackend,
        problems: &[Problem],
        quiet: bool,
    ) -> Result<(TrainedPolicy, EpisodeTrace)> {
        let cfg = self.cfg;
        self.space = Trainer::space_for(cfg, problems);
        let space = self.space.clone();
        let disc = Discretizer::fit(
            problems,
            cfg.bins_kappa,
            cfg.bins_norm,
            cfg.delta_c,
            cfg.delta_n,
        )
        .with_decay_bins(cfg.bins_decay);
        let mut q = QTable::new(disc.n_states(), space.clone());
        let mut rng = Rng::new(cfg.seed ^ 0xE715_0DE5);
        let mut trace = EpisodeTrace::default();
        let mut ws = SolveWorkspace::new();
        // (state, arm) pairs of the current rollout; reused across
        // problems
        let mut traj: Vec<(usize, usize)> = Vec::new();

        let states: Vec<usize> = problems.iter().map(|p| disc.state_of(p)).collect();

        for t in 0..cfg.episodes {
            let eps = epsilon_at(t, cfg.episodes, cfg.eps_min);
            let mut sum_r = 0.0;
            let mut sum_rpe = 0.0;
            let mut updates = 0usize;
            let mut explored_n = 0usize;
            for (pi, p) in problems.iter().enumerate() {
                let s0 = states[pi];
                let (ai0, explored) = select_action(&q, s0, eps, &mut rng);
                explored_n += explored as usize;
                let action0 = space.actions[ai0];
                traj.clear();
                traj.push((s0, ai0));
                let out = {
                    let qref = &q;
                    let rng_ref = &mut rng;
                    let traj_ref = &mut traj;
                    let mut first = true;
                    let mut decide = |phi_decay: f64, cur: &Action| -> Action {
                        // the first call is the same φ₃ = NaN state the
                        // initial arm was already selected at — don't
                        // draw (and record) twice for one decision
                        if first {
                            first = false;
                            return *cur;
                        }
                        let c = Context {
                            phi_kappa: phi_kappa_of(p.kappa_est, disc.delta_c),
                            phi_norm: phi_norm_of(p.norm_inf, disc.delta_n),
                            phi_decay,
                        };
                        let s = disc.state_of_context(c);
                        let cands: Vec<usize> = space
                            .actions
                            .iter()
                            .enumerate()
                            .filter(|(_, a)| step_candidate(a, cur))
                            .map(|(i, _)| i)
                            .collect();
                        // `cur` is always a member of the space (initial
                        // arm, or a prior candidate pick), so it matches
                        // its own filter and `cands` is never empty
                        let ai = if rng_ref.uniform() < eps {
                            cands[rng_ref.below(cands.len())]
                        } else {
                            let mut best = cands[0];
                            for &cand in &cands[1..] {
                                if qref.q(s, cand) > qref.q(s, best) {
                                    best = cand;
                                }
                            }
                            best
                        };
                        traj_ref.push((s, ai));
                        space.actions[ai]
                    };
                    let session = ProblemSession::new(&p.system);
                    solve_per_step_ws(
                        backend, &session, &p.b, &p.x_true, &action0, cfg, None, &mut ws,
                        &mut decide,
                    )?
                };
                // Monte-Carlo backup: every decision on the trajectory
                // shares the terminal outcome; the reward is evaluated
                // with that step's arm so each step pays its own cost.
                for &(s, ai) in traj.iter() {
                    let r = reward(
                        cfg,
                        &space.actions[ai],
                        &RewardInputs {
                            ferr: out.ferr,
                            nbe: out.nbe,
                            gmres_iters: out.gmres_iters,
                            kappa: p.kappa_est,
                            failed: out.failed,
                        },
                    );
                    let rpe = q.update(s, ai, r, cfg.alpha);
                    sum_r += r;
                    sum_rpe += rpe.abs();
                    updates += 1;
                }
            }
            let n = updates.max(1) as f64;
            trace.episode.push(t as f64);
            trace.mean_reward.push(sum_r / n);
            trace.mean_abs_rpe.push(sum_rpe / n);
            trace.epsilon.push(eps);
            trace.explored_frac.push(explored_n as f64 / problems.len() as f64);
            if !quiet && (t + 1) % 10 == 0 {
                eprintln!(
                    "  episode {:>3}/{} (per-step): eps={:.2} mean_reward={:+.3} mean|RPE|={:.3} updates={}",
                    t + 1,
                    cfg.episodes,
                    eps,
                    sum_r / n,
                    sum_rpe / n,
                    updates
                );
            }
        }
        Ok((TrainedPolicy { qtable: q, discretizer: disc }, trace))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend_native::NativeBackend;
    use crate::gen::{dense_dataset, sparse_dataset};

    fn quick_cfg() -> Config {
        let mut c = Config::tiny();
        c.size_min = 24;
        c.size_max = 48;
        c.episodes = 30;
        c.n_train = 10;
        c
    }

    #[test]
    fn training_learns_condition_dependent_policy() {
        let mut cfg = quick_cfg();
        cfg.weights = crate::util::config::Weights::W2;
        let problems = dense_dataset(&cfg, 12, 100);
        let backend = NativeBackend::new();
        let mut cache = SolveCache::new();
        let mut trainer = Trainer::new(&cfg, &mut cache);
        let (policy, trace) = trainer.train(&backend, &problems, true).unwrap();
        assert_eq!(trace.mean_reward.len(), cfg.episodes);
        // Every training state visited at least once per episode count.
        let visited: u64 = (0..policy.qtable.n_states)
            .map(|s| policy.qtable.total_visits(s))
            .sum();
        assert_eq!(visited as usize, cfg.episodes * problems.len());
        // ε decays: late episodes explore less than early ones.
        let early: f64 = trace.explored_frac[..5].iter().sum();
        let late: f64 = trace.explored_frac[cfg.episodes - 5..].iter().sum();
        assert!(late <= early);
        // Policy prefers cheaper-than-FP64 factorization for the easiest
        // systems under W2 (the paper's central qualitative claim).
        let easiest = problems
            .iter()
            .min_by(|a, b| a.kappa_est.partial_cmp(&b.kappa_est).unwrap())
            .unwrap();
        let act = policy.select(easiest);
        assert!(act.u_f < Prec::Fp64, "easy system got {act}");
    }

    #[test]
    fn cache_bounds_unique_solves() {
        let cfg = quick_cfg();
        let problems = dense_dataset(&cfg, 6, 200);
        let backend = NativeBackend::new();
        let mut cache = SolveCache::new();
        let mut trainer = Trainer::new(&cfg, &mut cache);
        trainer.train(&backend, &problems, true).unwrap();
        let space_len = trainer.space.len() as u64;
        let unique_max = problems.len() as u64 * space_len;
        // precompute sweeps every (problem, action) pair exactly once ...
        assert_eq!(cache.misses, unique_max);
        assert_eq!(cache.unique_solves() as u64, cache.misses);
        // ... so every training draw is a cache hit.
        assert_eq!(cache.hits, (cfg.episodes * problems.len()) as u64);
    }

    /// Wrapper backend counting `lu_factor` calls — also exercises the
    /// decorator pattern the `Send + Sync` trait enables.
    struct CountingBackend {
        inner: NativeBackend,
        factor_calls: std::sync::atomic::AtomicUsize,
    }

    impl CountingBackend {
        fn new() -> CountingBackend {
            CountingBackend {
                inner: NativeBackend::new(),
                factor_calls: std::sync::atomic::AtomicUsize::new(0),
            }
        }

        fn factor_calls(&self) -> usize {
            self.factor_calls.load(std::sync::atomic::Ordering::SeqCst)
        }
    }

    impl crate::solver::SolverBackend for CountingBackend {
        fn lu_factor(
            &self,
            s: &ProblemSession<'_>,
            p: Prec,
        ) -> anyhow::Result<crate::solver::LuHandle> {
            self.factor_calls
                .fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            self.inner.lu_factor(s, p)
        }

        fn lu_solve(
            &self,
            f: &crate::solver::LuHandle,
            b: &[f64],
            p: Prec,
        ) -> anyhow::Result<Vec<f64>> {
            self.inner.lu_solve(f, b, p)
        }

        fn residual(
            &self,
            s: &ProblemSession<'_>,
            x: &[f64],
            b: &[f64],
            p: Prec,
        ) -> anyhow::Result<Vec<f64>> {
            self.inner.residual(s, x, b, p)
        }

        fn gmres(
            &self,
            s: &ProblemSession<'_>,
            f: &crate::solver::LuHandle,
            r: &[f64],
            tol: f64,
            max_m: usize,
            p: Prec,
        ) -> anyhow::Result<crate::solver::GmresOutcome> {
            self.inner.gmres(s, f, r, tol, max_m, p)
        }

        fn name(&self) -> &'static str {
            "counting"
        }
    }

    #[test]
    fn outcome_fallback_memoizes_factorizations() {
        // With a large action space (k_top = 0 => 35 actions) precompute
        // is skipped and outcome() takes the fallback path; the (problem,
        // u_f) factor memo must dedupe LU work even in the trainer's
        // episode-like order (problem-major, actions spread over time)
        // and produce outcomes identical to the precompute path.
        let mut cfg = quick_cfg();
        cfg.k_top = 0;
        let problems = dense_dataset(&cfg, 3, 225);
        let backend = CountingBackend::new();
        let space = ActionSpace::reduced_top_k(0);
        assert!(space.len() > 12);
        let mut via_outcome = SolveCache::new();
        // action-major sweep = worst case for any single-problem memo:
        // consecutive calls never share a problem
        for (ai, action) in space.actions.iter().enumerate() {
            for (pi, _) in problems.iter().enumerate() {
                via_outcome
                    .outcome(&backend, &problems, pi, action, ai, &cfg)
                    .unwrap();
            }
        }
        // exactly one factorization per (problem, u_f) pair, not per action
        let distinct_uf = {
            let mut seen = std::collections::HashSet::new();
            for a in &space.actions {
                seen.insert(a.u_f as usize);
            }
            seen.len()
        };
        assert_eq!(backend.factor_calls(), problems.len() * distinct_uf);

        let mut via_precompute = SolveCache::new();
        via_precompute
            .precompute(&backend, &problems, &space, &cfg)
            .unwrap();
        for pi in 0..problems.len() {
            for ai in 0..space.len() {
                let a = via_outcome.cached(pi, ai).unwrap();
                let b = via_precompute.cached(pi, ai).unwrap();
                assert_eq!(a.ferr.to_bits(), b.ferr.to_bits(), "({pi},{ai})");
                assert_eq!(a.nbe.to_bits(), b.nbe.to_bits(), "({pi},{ai})");
                assert_eq!(a.gmres_iters, b.gmres_iters, "({pi},{ai})");
                assert_eq!(a.failed, b.failed, "({pi},{ai})");
            }
        }
    }

    #[test]
    fn cache_shared_across_weight_settings_skips_resolves() {
        let mut cfg = quick_cfg();
        let problems = dense_dataset(&cfg, 5, 250);
        let mut cache = SolveCache::new();
        Trainer::new(&cfg, &mut cache)
            .train(&NativeBackend::new(), &problems, true)
            .unwrap();
        let misses_after_w1 = cache.misses;
        cfg.weights = crate::util::config::Weights::W2;
        Trainer::new(&cfg, &mut cache)
            .train(&NativeBackend::new(), &problems, true)
            .unwrap();
        // W2 re-training mostly reuses W1's solve outcomes.
        assert!(
            cache.misses - misses_after_w1 < misses_after_w1,
            "W2 resolved too much: {} vs {}",
            cache.misses - misses_after_w1,
            misses_after_w1
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = quick_cfg();
        let problems = dense_dataset(&cfg, 5, 300);
        let mut c1 = SolveCache::new();
        let mut c2 = SolveCache::new();
        let mut t1 = Trainer::new(&cfg, &mut c1);
        let (p1, tr1) = t1.train(&NativeBackend::new(), &problems, true).unwrap();
        let mut t2 = Trainer::new(&cfg, &mut c2);
        let (p2, tr2) = t2.train(&NativeBackend::new(), &problems, true).unwrap();
        assert_eq!(tr1.mean_reward, tr2.mean_reward);
        for s in 0..p1.qtable.n_states {
            assert_eq!(p1.qtable.argmax(s), p2.qtable.argmax(s));
        }
    }

    #[test]
    fn policy_roundtrips_through_disk() {
        let cfg = quick_cfg();
        let problems = dense_dataset(&cfg, 4, 400);
        let mut cache = SolveCache::new();
        let mut trainer = Trainer::new(&cfg, &mut cache);
        let (policy, _) = trainer
            .train(&NativeBackend::new(), &problems, true)
            .unwrap();
        let path = std::env::temp_dir().join("pa_policy_test.json");
        policy.save(path.to_str().unwrap()).unwrap();
        let back = TrainedPolicy::load(path.to_str().unwrap()).unwrap();
        for p in &problems {
            assert_eq!(policy.select(p), back.select(p));
        }
    }

    #[test]
    fn policy_json_rejects_schema_and_hash_mismatch() {
        let cfg = quick_cfg();
        let problems = dense_dataset(&cfg, 3, 450);
        let mut cache = SolveCache::new();
        let (policy, _) = Trainer::new(&cfg, &mut cache)
            .train(&NativeBackend::new(), &problems, true)
            .unwrap();
        let text = policy.to_json().to_string();

        // wrong version
        let bad = text.replacen("\"schema_version\":3.0", "\"schema_version\":99.0", 1);
        assert_ne!(bad, text);
        let err = TrainedPolicy::from_json(&json::parse(&bad).unwrap()).unwrap_err();
        assert!(err.to_string().contains("schema_version"), "{err}");

        // legacy versions get version-specific migration hints
        let v1 = text.replacen("\"schema_version\":3.0", "\"schema_version\":1.0", 1);
        let err = TrainedPolicy::from_json(&json::parse(&v1).unwrap()).unwrap_err();
        assert!(err.to_string().contains("solver-family"), "{err}");
        let v2 = text.replacen("\"schema_version\":3.0", "\"schema_version\":2.0", 1);
        let err = TrainedPolicy::from_json(&json::parse(&v2).unwrap()).unwrap_err();
        assert!(err.to_string().contains("preconditioner/restart"), "{err}");

        // missing version (schema_version sorts last in the object)
        let missing = text.replacen(",\"schema_version\":3.0", "", 1);
        assert_ne!(missing, text);
        let err = TrainedPolicy::from_json(&json::parse(&missing).unwrap()).unwrap_err();
        assert!(err.to_string().contains("schema_version"), "{err}");

        // tampered action-space hash
        let hash = format!("{:016x}", action_space_hash(&policy.qtable.space));
        let tampered = text.replacen(&hash, "deadbeefdeadbeef", 1);
        assert_ne!(tampered, text);
        let err = TrainedPolicy::from_json(&json::parse(&tampered).unwrap()).unwrap_err();
        assert!(err.to_string().contains("action-space hash"), "{err}");
    }

    #[test]
    fn corrupt_policy_fixture_is_rejected_not_loaded() {
        // the committed fixture is policy_golden_v3.json with one Q value
        // swapped for 1e999 (parses to +inf in our reader) — the exact
        // artifact a byte-flip or hand edit produces. Loading must fail
        // loudly, never hand inference an infinite Q.
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../testdata/policy_corrupt_nan.json");
        let err = TrainedPolicy::load(path).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("not finite"), "{msg}");
        // control: the clean golden fixture still loads
        let golden = concat!(env!("CARGO_MANIFEST_DIR"), "/../testdata/policy_golden_v3.json");
        let pol = TrainedPolicy::load(golden).unwrap();
        assert_eq!(pol.qtable.n_states, 2);
        // and its ranked view agrees with the greedy pick per state
        for s in 0..pol.qtable.n_states {
            let ranked = pol.qtable.visited_ranked(s);
            assert_eq!(ranked.first().copied(), pol.qtable.argmax_visited(s));
        }
    }

    #[test]
    fn spd_dataset_routes_to_extended_space_dense_stays_lu_only() {
        let mut cfg = quick_cfg();
        cfg.size_min = 40;
        cfg.size_max = 56;
        cfg.episodes = 12;
        let dense = dense_dataset(&cfg, 4, 600);
        let sparse = sparse_dataset(&cfg, 4, 600);
        assert!(sparse.iter().all(|p| p.spd));
        assert!(dense.iter().all(|p| !p.spd));
        // static routing helper agrees with what train() installs
        assert!(!Trainer::space_for(&cfg, &dense).has_family(SolverFamily::CgIr));
        assert!(Trainer::space_for(&cfg, &sparse).has_family(SolverFamily::CgIr));
        // families = "lu-only" pins the paper's space even on SPD sets
        // (the sparse repro tables rely on this opt-out)
        let mut lu_cfg = cfg.clone();
        lu_cfg.families = "lu-only".to_string();
        assert!(!Trainer::space_for(&lu_cfg, &sparse).has_family(SolverFamily::CgIr));
        // precond_arms is opt-in: off ⇒ byte-stable extended space; on ⇒
        // the precond/restart-grown space, and only on the SPD route
        let mut pc_cfg = cfg.clone();
        pc_cfg.precond_arms = true;
        let grown = Trainer::space_for(&pc_cfg, &sparse);
        assert_eq!(grown.len(), ActionSpace::extended_precond_top_k(cfg.k_top).len());
        assert!(grown.actions.iter().any(|a| !a.is_legacy_shape()));
        assert_eq!(
            Trainer::space_for(&pc_cfg, &dense).len(),
            ActionSpace::reduced_top_k(cfg.k_top).len()
        );

        let backend = NativeBackend::new();
        let mut cache = SolveCache::new();
        let mut tr = Trainer::new(&cfg, &mut cache);
        let (policy, _) = tr.train(&backend, &sparse, true).unwrap();
        assert!(policy.qtable.space.has_family(SolverFamily::CgIr));
        assert!(policy.qtable.space.has_family(SolverFamily::LuIr));
        assert_eq!(
            policy.qtable.space.len(),
            2 * ActionSpace::reduced_top_k(cfg.k_top).len()
        );
        // CG actions were actually exercised (precompute sweeps all)
        let visited_cg = (0..policy.qtable.n_states).any(|s| {
            policy.qtable.space.actions.iter().enumerate().any(|(ai, a)| {
                a.solver == SolverFamily::CgIr && policy.qtable.visits(s, ai) > 0
            })
        });
        assert!(visited_cg, "extended training never tried a CG action");

        let mut cache2 = SolveCache::new();
        let mut tr2 = Trainer::new(&cfg, &mut cache2);
        let (policy_d, _) = tr2.train(&backend, &dense, true).unwrap();
        assert!(!policy_d.qtable.space.has_family(SolverFamily::CgIr));
        // the two spaces hash differently — policies cannot cross-load
        assert_ne!(
            action_space_hash(&policy.qtable.space),
            action_space_hash(&policy_d.qtable.space)
        );
    }

    #[test]
    fn family_swapped_spaces_hash_differently() {
        let lu = ActionSpace::reduced_top_k(9);
        let cg = ActionSpace {
            actions: lu
                .actions
                .iter()
                .map(|a| a.with_solver(SolverFamily::CgIr))
                .collect(),
        };
        assert_ne!(action_space_hash(&lu), action_space_hash(&cg));
    }

    #[test]
    fn hash_covers_precond_and_restart_dimensions() {
        use crate::bandit::action::Precond;
        let base = ActionSpace::extended_top_k(9);
        let precond_swapped = ActionSpace {
            actions: base
                .actions
                .iter()
                .map(|a| {
                    if a.solver == SolverFamily::CgIr {
                        a.with_precond(Precond::Ssor)
                    } else {
                        *a
                    }
                })
                .collect(),
        };
        let restart_swapped = ActionSpace {
            actions: base
                .actions
                .iter()
                .map(|a| {
                    if a.solver == SolverFamily::LuIr {
                        a.with_restart(8)
                    } else {
                        *a
                    }
                })
                .collect(),
        };
        assert_ne!(action_space_hash(&base), action_space_hash(&precond_swapped));
        assert_ne!(action_space_hash(&base), action_space_hash(&restart_swapped));
        assert_ne!(
            action_space_hash(&precond_swapped),
            action_space_hash(&restart_swapped)
        );
        // the grown space hashes differently from its legacy prefix
        assert_ne!(
            action_space_hash(&ActionSpace::extended_precond_top_k(9)),
            action_space_hash(&base)
        );
    }

    #[test]
    fn per_step_training_is_deterministic_and_policy_roundtrips() {
        let mut cfg = quick_cfg();
        cfg.size_min = 32;
        cfg.size_max = 48;
        cfg.episodes = 8;
        cfg.per_step = true;
        cfg.bins_decay = 2;
        let problems = sparse_dataset(&cfg, 4, 700);
        let backend = NativeBackend::new();
        let mut c1 = SolveCache::new();
        let (p1, tr1) = Trainer::new(&cfg, &mut c1)
            .train_per_step(&backend, &problems, true)
            .unwrap();
        let mut c2 = SolveCache::new();
        let (p2, tr2) = Trainer::new(&cfg, &mut c2)
            .train_per_step(&backend, &problems, true)
            .unwrap();
        // the serial rollout loop is deterministic given the seed
        assert_eq!(tr1.mean_reward, tr2.mean_reward);
        assert_eq!(p1.qtable.fingerprint(), p2.qtable.fingerprint());
        // the decay axis widened the state space
        assert_eq!(
            p1.discretizer.n_states(),
            cfg.bins_kappa * cfg.bins_norm * cfg.bins_decay
        );
        // the artifact (with its decay-extended discretizer) roundtrips
        let path = std::env::temp_dir().join("pa_policy_per_step_test.json");
        p1.save(path.to_str().unwrap()).unwrap();
        let back = TrainedPolicy::load(path.to_str().unwrap()).unwrap();
        assert_eq!(back.discretizer, p1.discretizer);
        assert_eq!(back.qtable.fingerprint(), p1.qtable.fingerprint());
        // decide_step never de-escalates or changes the solve-level shape
        let p0 = &problems[0];
        for a0 in &p1.qtable.space.actions {
            for phi in [f64::NAN, -4.0, -0.1] {
                let next = p1.decide_step(p0.kappa_est, p0.norm_inf, phi, a0);
                assert_eq!(next.solver, a0.solver);
                assert_eq!(next.u_f, a0.u_f);
                assert_eq!(next.precond, a0.precond);
                assert_eq!(next.restart_m, a0.restart_m);
                assert!(next.u >= a0.u && next.u_g >= a0.u_g && next.u_r >= a0.u_r);
            }
        }
    }

    #[test]
    fn rpe_decreases_as_learning_converges() {
        let mut cfg = quick_cfg();
        cfg.episodes = 60;
        let problems = dense_dataset(&cfg, 8, 500);
        let mut cache = SolveCache::new();
        let mut trainer = Trainer::new(&cfg, &mut cache);
        let (_, trace) = trainer
            .train(&NativeBackend::new(), &problems, true)
            .unwrap();
        let early: f64 = trace.mean_abs_rpe[..10].iter().sum::<f64>() / 10.0;
        let late: f64 = trace.mean_abs_rpe[50..].iter().sum::<f64>() / 10.0;
        assert!(
            late < early,
            "mean|RPE| should shrink: early {early:.3} late {late:.3}"
        );
    }
}
