//! Training loop (Alg. 3) and the trained-policy artifact.
//!
//! The environment is deterministic per (system, action) — the solver has
//! no stochastic component — so solve outcomes are memoized. Unique work
//! is bounded by N_train × |𝒜_reduced| (≤ 3500 at paper scale) instead of
//! T × N_train (10⁴); everything else is O(1) lookups. This is the key
//! L3 optimization that makes paper-scale training tractable on one core
//! (EXPERIMENTS.md §Perf).

use std::collections::HashMap;

use anyhow::Result;

use crate::bandit::action::ActionSpace;
use crate::bandit::policy::{epsilon_at, select_action};
use crate::bandit::qtable::QTable;
use crate::bandit::reward::{reward, RewardInputs};
use crate::features::Discretizer;
use crate::gen::Problem;
use crate::solver::ir::gmres_ir;
use crate::solver::SolverBackend;
use crate::util::config::Config;
use crate::util::json::{self, Value};
use crate::util::rng::Rng;

/// Per-episode training telemetry (appendix Figures 5–12: total reward
/// and mean |RPE| per episode).
#[derive(Clone, Debug, Default)]
pub struct EpisodeTrace {
    pub episode: Vec<f64>,
    pub mean_reward: Vec<f64>,
    pub mean_abs_rpe: Vec<f64>,
    pub epsilon: Vec<f64>,
    pub explored_frac: Vec<f64>,
}

/// Outcome signature kept in the solve cache (x itself is not needed for
/// training — only the reward inputs).
#[derive(Clone, Copy, Debug)]
pub struct CachedOutcome {
    pub ferr: f64,
    pub nbe: f64,
    pub outer_iters: usize,
    pub gmres_iters: usize,
    pub failed: bool,
}

/// Memoized solve outcomes keyed by (problem index, action index).
///
/// Rewards depend on the weight setting but *outcomes* do not, so one
/// cache serves both W1 and W2 training runs at the same τ — the
/// coordinator exploits this to halve the dominant cost of a table run.
#[derive(Default)]
pub struct SolveCache {
    map: HashMap<(usize, usize), CachedOutcome>,
    pub hits: u64,
    pub misses: u64,
}

impl SolveCache {
    pub fn new() -> SolveCache {
        SolveCache::default()
    }

    pub fn unique_solves(&self) -> usize {
        self.map.len()
    }

    /// Get or compute the outcome of solving `problems[pi]` with `action`.
    pub fn outcome(
        &mut self,
        backend: &mut dyn SolverBackend,
        problems: &[Problem],
        pi: usize,
        action: &crate::bandit::action::Action,
        ai: usize,
        cfg: &Config,
    ) -> Result<CachedOutcome> {
        if let Some(o) = self.map.get(&(pi, ai)) {
            self.hits += 1;
            return Ok(*o);
        }
        self.misses += 1;
        let out = gmres_ir(backend, &problems[pi], action, cfg)?;
        let c = CachedOutcome {
            ferr: out.ferr,
            nbe: out.nbe,
            outer_iters: out.outer_iters,
            gmres_iters: out.gmres_iters,
            failed: out.failed,
        };
        self.map.insert((pi, ai), c);
        Ok(c)
    }

    /// Exhaustive per-problem precompute (§Perf): with the reduced action
    /// space (k_top = 9), ε-greedy training ends up visiting nearly every
    /// (problem, action) pair anyway, so computing them problem-by-problem
    /// costs the same number of solves while letting every action with the
    /// same u_f share one LU factorization (9 actions / 4 factorizations)
    /// and the backend reuse its chopped-A cache across actions.
    pub fn precompute(
        &mut self,
        backend: &mut dyn SolverBackend,
        problems: &[Problem],
        space: &ActionSpace,
        cfg: &Config,
    ) -> Result<()> {
        use crate::chop::Prec;
        use crate::solver::ir::gmres_ir_prefactored;
        for (pi, p) in problems.iter().enumerate() {
            if (0..space.len()).all(|ai| self.map.contains_key(&(pi, ai))) {
                continue;
            }
            backend.reset();
            // Factor once per u_f actually used by the space.
            let mut factors: [Option<Option<crate::solver::LuHandle>>; 4] =
                [None, None, None, None];
            for (ai, action) in space.actions.iter().enumerate() {
                if self.map.contains_key(&(pi, ai)) {
                    continue;
                }
                self.misses += 1;
                let fi = action.u_f as usize;
                if factors[fi].is_none() {
                    factors[fi] = Some(backend.lu_factor(&p.a, Prec::from_index(fi)).ok());
                }
                let out = match factors[fi].as_ref().unwrap() {
                    Some(f) => gmres_ir_prefactored(backend, p, action, cfg, Some(f))?,
                    None => {
                        // factorization breakdown: same failure outcome
                        // gmres_ir would produce
                        crate::solver::ir::SolveOutcome {
                            x: vec![f64::NAN; p.n],
                            ferr: f64::INFINITY,
                            nbe: f64::INFINITY,
                            eps_max: f64::INFINITY,
                            outer_iters: 0,
                            gmres_iters: 0,
                            stop: crate::solver::ir::StopReason::Failure,
                            failed: true,
                        }
                    }
                };
                self.map.insert(
                    (pi, ai),
                    CachedOutcome {
                        ferr: out.ferr,
                        nbe: out.nbe,
                        outer_iters: out.outer_iters,
                        gmres_iters: out.gmres_iters,
                        failed: out.failed,
                    },
                );
            }
        }
        Ok(())
    }
}

/// The trained artifact: Q-table + the discretizer it was fitted with.
#[derive(Clone, Debug)]
pub struct TrainedPolicy {
    pub qtable: QTable,
    pub discretizer: Discretizer,
}

impl TrainedPolicy {
    /// Greedy inference (Alg. 1 line 18 / Alg. 3 line 23), restricted to
    /// actions the agent actually tried in this state; unvisited states
    /// fall back to the safe all-FP64 configuration.
    pub fn select(&self, p: &Problem) -> crate::bandit::action::Action {
        let s = self.discretizer.state_of(p);
        self.qtable.best_action_visited(s)
    }

    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("qtable", self.qtable.to_json()),
            ("discretizer", self.discretizer.to_json()),
        ])
    }

    pub fn from_json(v: &Value) -> Result<TrainedPolicy> {
        Ok(TrainedPolicy {
            qtable: QTable::from_json(v.get("qtable")?)?,
            discretizer: Discretizer::from_json(v.get("discretizer")?)?,
        })
    }

    pub fn save(&self, path: &str) -> Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &str) -> Result<TrainedPolicy> {
        let text = std::fs::read_to_string(path)?;
        TrainedPolicy::from_json(&json::parse(&text)?)
    }
}

/// Alg.-3 trainer. Borrows a [`SolveCache`] so multiple trainings (e.g.
/// W1 and W2 at the same τ) share solve outcomes.
pub struct Trainer<'a> {
    pub cfg: &'a Config,
    pub space: ActionSpace,
    pub cache: &'a mut SolveCache,
}

impl<'a> Trainer<'a> {
    pub fn new(cfg: &'a Config, cache: &'a mut SolveCache) -> Trainer<'a> {
        Trainer {
            cfg,
            space: ActionSpace::reduced_top_k(cfg.k_top),
            cache,
        }
    }

    /// Train on `problems` for `cfg.episodes` episodes (Alg. 3 lines
    /// 5–22). Returns the policy and the per-episode trace.
    pub fn train(
        &mut self,
        backend: &mut dyn SolverBackend,
        problems: &[Problem],
        quiet: bool,
    ) -> Result<(TrainedPolicy, EpisodeTrace)> {
        let cfg = self.cfg;
        let disc = Discretizer::fit(
            problems,
            cfg.bins_kappa,
            cfg.bins_norm,
            cfg.delta_c,
            cfg.delta_n,
        );
        let mut q = QTable::new(disc.n_states(), self.space.clone());
        let mut rng = Rng::new(cfg.seed ^ 0xE715_0DE5);
        let mut trace = EpisodeTrace::default();

        // §Perf: exhaustive per-problem precompute with LU sharing when
        // the action space is small enough that training would visit
        // (almost) everything anyway.
        if self.space.len() <= 12 {
            let space = self.space.clone();
            self.cache.precompute(backend, problems, &space, cfg)?;
        }

        // Precompute states (features are solve-independent).
        let states: Vec<usize> = problems.iter().map(|p| disc.state_of(p)).collect();

        for t in 0..cfg.episodes {
            let eps = epsilon_at(t, cfg.episodes, cfg.eps_min);
            let mut sum_r = 0.0;
            let mut sum_rpe = 0.0;
            let mut explored_n = 0usize;
            for (pi, p) in problems.iter().enumerate() {
                let s = states[pi];
                let (ai, explored) = select_action(&q, s, eps, &mut rng);
                explored_n += explored as usize;
                let action = self.space.actions[ai];
                let o = self
                    .cache
                    .outcome(backend, problems, pi, &action, ai, cfg)?;
                let r = reward(
                    cfg,
                    &self.space.actions[ai],
                    &RewardInputs {
                        ferr: o.ferr,
                        nbe: o.nbe,
                        gmres_iters: o.gmres_iters,
                        kappa: p.kappa_est,
                        failed: o.failed,
                    },
                );
                let rpe = q.update(s, ai, r, cfg.alpha);
                sum_r += r;
                sum_rpe += rpe.abs();
            }
            let n = problems.len() as f64;
            trace.episode.push(t as f64);
            trace.mean_reward.push(sum_r / n);
            trace.mean_abs_rpe.push(sum_rpe / n);
            trace.epsilon.push(eps);
            trace.explored_frac.push(explored_n as f64 / n);
            if !quiet && (t + 1) % 10 == 0 {
                eprintln!(
                    "  episode {:>3}/{}: eps={:.2} mean_reward={:+.3} mean|RPE|={:.3} cache {}/{}",
                    t + 1,
                    cfg.episodes,
                    eps,
                    sum_r / n,
                    sum_rpe / n,
                    self.cache.hits,
                    self.cache.hits + self.cache.misses
                );
            }
        }
        Ok((TrainedPolicy { qtable: q, discretizer: disc }, trace))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend_native::NativeBackend;
    use crate::chop::Prec;
    use crate::gen::dense_dataset;

    fn quick_cfg() -> Config {
        let mut c = Config::tiny();
        c.size_min = 24;
        c.size_max = 48;
        c.episodes = 30;
        c.n_train = 10;
        c
    }

    #[test]
    fn training_learns_condition_dependent_policy() {
        let mut cfg = quick_cfg();
        cfg.weights = crate::util::config::Weights::W2;
        let problems = dense_dataset(&cfg, 12, 100);
        let mut backend = NativeBackend::new();
        let mut cache = SolveCache::new();
        let mut trainer = Trainer::new(&cfg, &mut cache);
        let (policy, trace) = trainer.train(&mut backend, &problems, true).unwrap();
        assert_eq!(trace.mean_reward.len(), cfg.episodes);
        // Every training state visited at least once per episode count.
        let visited: u64 = (0..policy.qtable.n_states)
            .map(|s| policy.qtable.total_visits(s))
            .sum();
        assert_eq!(visited as usize, cfg.episodes * problems.len());
        // ε decays: late episodes explore less than early ones.
        let early: f64 = trace.explored_frac[..5].iter().sum();
        let late: f64 = trace.explored_frac[cfg.episodes - 5..].iter().sum();
        assert!(late <= early);
        // Policy prefers cheaper-than-FP64 factorization for the easiest
        // systems under W2 (the paper's central qualitative claim).
        let easiest = problems
            .iter()
            .min_by(|a, b| a.kappa_est.partial_cmp(&b.kappa_est).unwrap())
            .unwrap();
        let act = policy.select(easiest);
        assert!(act.u_f < Prec::Fp64, "easy system got {act}");
    }

    #[test]
    fn cache_bounds_unique_solves() {
        let cfg = quick_cfg();
        let problems = dense_dataset(&cfg, 6, 200);
        let mut backend = NativeBackend::new();
        let mut cache = SolveCache::new();
        let mut trainer = Trainer::new(&cfg, &mut cache);
        trainer.train(&mut backend, &problems, true).unwrap();
        let space_len = trainer.space.len() as u64;
        let unique_max = problems.len() as u64 * space_len;
        // precompute sweeps every (problem, action) pair exactly once ...
        assert_eq!(cache.misses, unique_max);
        assert_eq!(cache.unique_solves() as u64, cache.misses);
        // ... so every training draw is a cache hit.
        assert_eq!(cache.hits, (cfg.episodes * problems.len()) as u64);
    }

    #[test]
    fn cache_shared_across_weight_settings_skips_resolves() {
        let mut cfg = quick_cfg();
        let problems = dense_dataset(&cfg, 5, 250);
        let mut cache = SolveCache::new();
        Trainer::new(&cfg, &mut cache)
            .train(&mut NativeBackend::new(), &problems, true)
            .unwrap();
        let misses_after_w1 = cache.misses;
        cfg.weights = crate::util::config::Weights::W2;
        Trainer::new(&cfg, &mut cache)
            .train(&mut NativeBackend::new(), &problems, true)
            .unwrap();
        // W2 re-training mostly reuses W1's solve outcomes.
        assert!(
            cache.misses - misses_after_w1 < misses_after_w1,
            "W2 resolved too much: {} vs {}",
            cache.misses - misses_after_w1,
            misses_after_w1
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = quick_cfg();
        let problems = dense_dataset(&cfg, 5, 300);
        let mut c1 = SolveCache::new();
        let mut c2 = SolveCache::new();
        let mut t1 = Trainer::new(&cfg, &mut c1);
        let (p1, tr1) = t1.train(&mut NativeBackend::new(), &problems, true).unwrap();
        let mut t2 = Trainer::new(&cfg, &mut c2);
        let (p2, tr2) = t2.train(&mut NativeBackend::new(), &problems, true).unwrap();
        assert_eq!(tr1.mean_reward, tr2.mean_reward);
        for s in 0..p1.qtable.n_states {
            assert_eq!(p1.qtable.argmax(s), p2.qtable.argmax(s));
        }
    }

    #[test]
    fn policy_roundtrips_through_disk() {
        let cfg = quick_cfg();
        let problems = dense_dataset(&cfg, 4, 400);
        let mut cache = SolveCache::new();
        let mut trainer = Trainer::new(&cfg, &mut cache);
        let (policy, _) = trainer
            .train(&mut NativeBackend::new(), &problems, true)
            .unwrap();
        let path = std::env::temp_dir().join("pa_policy_test.json");
        policy.save(path.to_str().unwrap()).unwrap();
        let back = TrainedPolicy::load(path.to_str().unwrap()).unwrap();
        for p in &problems {
            assert_eq!(policy.select(p), back.select(p));
        }
    }

    #[test]
    fn rpe_decreases_as_learning_converges() {
        let mut cfg = quick_cfg();
        cfg.episodes = 60;
        let problems = dense_dataset(&cfg, 8, 500);
        let mut cache = SolveCache::new();
        let mut trainer = Trainer::new(&cfg, &mut cache);
        let (_, trace) = trainer
            .train(&mut NativeBackend::new(), &problems, true)
            .unwrap();
        let early: f64 = trace.mean_abs_rpe[..10].iter().sum::<f64>() / 10.0;
        let late: f64 = trace.mean_abs_rpe[50..].iter().sum::<f64>() / 10.0;
        assert!(
            late < early,
            "mean|RPE| should shrink: early {early:.3} late {late:.3}"
        );
    }
}
