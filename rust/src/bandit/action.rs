//! Joint action space (eq. 1) and its structured reduction (eq. 11–12).
//!
//! An action is the precision 4-tuple a = (u_f, u, u_g, u_r) for the four
//! precision-controlled steps of GMRES-IR. The reduced space keeps only
//! monotone tuples u_f ≤ u ≤ u_g ≤ u_r (ordered by significand bits),
//! giving C(m+k−1, k) combinations — 35 for m=4 precisions, k=4 steps, an
//! ~86% cut from the full 256 (§3.2).

use crate::chop::Prec;

/// A precision configuration for one GMRES-IR solve.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Action {
    /// u_f — LU factorization + initial solve
    pub u_f: Prec,
    /// u — solution update x_{i+1} = x_i + z_i
    pub u: Prec,
    /// u_g — GMRES working precision (incl. preconditioner application)
    pub u_g: Prec,
    /// u_r — residual computation
    pub u_r: Prec,
}

impl Action {
    pub const FP64: Action = Action {
        u_f: Prec::Fp64,
        u: Prec::Fp64,
        u_g: Prec::Fp64,
        u_r: Prec::Fp64,
    };

    /// The tuple in paper order (u_f, u, u_g, u_r).
    pub fn tuple(&self) -> [Prec; 4] {
        [self.u_f, self.u, self.u_g, self.u_r]
    }

    /// Monotone constraint of eq. (11): u_f ≤ u ≤ u_g ≤ u_r by
    /// significand bits.
    pub fn is_monotone(&self) -> bool {
        self.u_f <= self.u && self.u <= self.u_g && self.u_g <= self.u_r
    }

    pub fn name(&self) -> String {
        format!(
            "({},{},{},{})",
            self.u_f.name(),
            self.u.name(),
            self.u_g.name(),
            self.u_r.name()
        )
    }
}

impl std::fmt::Display for Action {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// The reduced action space 𝒜_reduced (plus helpers over the full space).
#[derive(Clone, Debug)]
pub struct ActionSpace {
    pub actions: Vec<Action>,
}

impl ActionSpace {
    /// All m^k joint actions (k=4 fixed by GMRES-IR).
    pub fn full() -> ActionSpace {
        let mut actions = Vec::new();
        for &u_f in &Prec::ALL {
            for &u in &Prec::ALL {
                for &u_g in &Prec::ALL {
                    for &u_r in &Prec::ALL {
                        actions.push(Action { u_f, u, u_g, u_r });
                    }
                }
            }
        }
        ActionSpace { actions }
    }

    /// The monotone reduction of eq. (11): non-decreasing tuples only.
    pub fn reduced() -> ActionSpace {
        let mut actions: Vec<Action> = ActionSpace::full()
            .actions
            .into_iter()
            .filter(Action::is_monotone)
            .collect();
        // Deterministic order: lexicographic by (u_f, u, u_g, u_r),
        // i.e. cheapest-first; ties in Q resolve toward lower precision.
        actions.sort_by_key(|a| a.tuple().map(|p| p as u8));
        ActionSpace { actions }
    }

    /// Optional top-k pruning (§5: "further pruned ... one-fourth of the
    /// valid precision combinations"). Keeps a spread across the cost
    /// spectrum: every ceil(len/k)-th action of the cost-ordered list,
    /// always retaining the all-FP64 fallback.
    pub fn reduced_top_k(k_top: usize) -> ActionSpace {
        let all = ActionSpace::reduced();
        if k_top == 0 || k_top >= all.len() {
            return all;
        }
        let stride = (all.len() as f64 / k_top as f64).ceil() as usize;
        let mut actions: Vec<Action> = all.actions.iter().copied().step_by(stride).collect();
        if !actions.contains(&Action::FP64) {
            actions.push(Action::FP64);
        }
        ActionSpace { actions }
    }

    pub fn len(&self) -> usize {
        self.actions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.actions.is_empty()
    }

    pub fn index_of(&self, a: &Action) -> Option<usize> {
        self.actions.iter().position(|x| x == a)
    }

    /// C(m+k−1, k) — the reduced-space cardinality formula (eq. 12).
    pub fn reduced_cardinality(m: usize, k: usize) -> usize {
        // binomial(m+k-1, k) with small arguments
        let n = m + k - 1;
        let mut num: u128 = 1;
        let mut den: u128 = 1;
        for i in 0..k {
            num *= (n - i) as u128;
            den *= (i + 1) as u128;
        }
        (num / den) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_space_has_256_actions() {
        assert_eq!(ActionSpace::full().len(), 256); // m^k = 4^4 (eq. 1)
    }

    #[test]
    fn reduced_space_has_35_actions() {
        // §3.2: "we prune the action space from 256 to 35, ~86%"
        let r = ActionSpace::reduced();
        assert_eq!(r.len(), 35);
        assert_eq!(ActionSpace::reduced_cardinality(4, 4), 35);
        let cut = 1.0 - 35.0 / 256.0;
        assert!(cut > 0.86 && cut < 0.87);
    }

    #[test]
    fn reduced_cardinality_formula() {
        assert_eq!(ActionSpace::reduced_cardinality(2, 2), 3);
        assert_eq!(ActionSpace::reduced_cardinality(3, 2), 6);
        assert_eq!(ActionSpace::reduced_cardinality(7, 4), 210);
    }

    #[test]
    fn all_reduced_actions_are_monotone_and_unique() {
        let r = ActionSpace::reduced();
        for a in &r.actions {
            assert!(a.is_monotone(), "{a}");
        }
        let mut set = std::collections::HashSet::new();
        for a in &r.actions {
            assert!(set.insert(*a), "duplicate {a}");
        }
    }

    #[test]
    fn reduced_contains_extremes() {
        let r = ActionSpace::reduced();
        assert!(r.index_of(&Action::FP64).is_some());
        let all_bf16 = Action {
            u_f: Prec::Bf16,
            u: Prec::Bf16,
            u_g: Prec::Bf16,
            u_r: Prec::Bf16,
        };
        assert!(r.index_of(&all_bf16).is_some());
        // the paper's flagship mixed config: low factorization, high residual
        let flagship = Action {
            u_f: Prec::Bf16,
            u: Prec::Fp64,
            u_g: Prec::Fp64,
            u_r: Prec::Fp64,
        };
        assert!(r.index_of(&flagship).is_some());
    }

    #[test]
    fn non_monotone_rejected() {
        let bad = Action {
            u_f: Prec::Fp64,
            u: Prec::Bf16,
            u_g: Prec::Fp64,
            u_r: Prec::Fp64,
        };
        assert!(!bad.is_monotone());
        assert!(ActionSpace::reduced().index_of(&bad).is_none());
    }

    #[test]
    fn top_k_pruning_keeps_fp64_and_spread() {
        // §5: one-fourth of the 35 valid combinations
        let pruned = ActionSpace::reduced_top_k(9);
        assert!(pruned.len() <= 10 && pruned.len() >= 8, "{}", pruned.len());
        assert!(pruned.index_of(&Action::FP64).is_some());
        // includes at least one low-precision action
        assert!(pruned.actions.iter().any(|a| a.u_f == Prec::Bf16));
        // k_top = 0 disables pruning
        assert_eq!(ActionSpace::reduced_top_k(0).len(), 35);
        assert_eq!(ActionSpace::reduced_top_k(100).len(), 35);
    }

    #[test]
    fn property_reduction_matches_formula_for_all_mk() {
        // enumerate non-decreasing tuples for m in 1..=4 (restricting to
        // prefixes of Prec::ALL), k = 4, and compare with the formula
        for m in 1..=4usize {
            let count = ActionSpace::full()
                .actions
                .iter()
                .filter(|a| a.is_monotone())
                .filter(|a| a.tuple().iter().all(|p| (*p as usize) < m))
                .count();
            assert_eq!(count, ActionSpace::reduced_cardinality(m, 4), "m={m}");
        }
    }
}
