//! Joint action space (eq. 1) and its structured reduction (eq. 11–12),
//! extended with the solver family dimension.
//!
//! An action is a **(solver family, precision 4-tuple)** pair: which
//! refinement engine runs the solve ([`SolverFamily`]) and the precision
//! a = (u_f, u, u_g, u_r) for its four precision-controlled steps. For
//! the LU family these are the paper's GMRES-IR steps; for the CG family
//! the same four slots map onto the CG-IR analogues (see
//! `solver::family`):
//!
//! | slot | LU/GMRES-IR | CG-IR |
//! |---|---|---|
//! | u_f | LU factorization + initial solve | Jacobi preconditioner build + diagonal initial solve |
//! | u   | solution update | solution update |
//! | u_g | inner GMRES working precision | inner PCG working precision (matvecs) |
//! | u_r | residual computation | residual computation |
//!
//! The per-family reduced space keeps only monotone tuples
//! u_f ≤ u ≤ u_g ≤ u_r (ordered by significand bits), giving
//! C(m+k−1, k) combinations — 35 for m=4 precisions, k=4 steps, an ~86%
//! cut from the full 256 (§3.2). The *extended* space is the union over
//! both families (70 actions, or 2·(k_top+1)-ish after pruning).
//!
//! Since schema v3 (ROADMAP item 4, the PEARL axis) an action also
//! carries two solver hyperparameters: a [`Precond`] choice (which
//! preconditioner the inner solver applies) and a GMRES restart length
//! `restart_m` (0 = the historical single-cycle inner solve). Every
//! pre-v3 action keeps its family's *default* preconditioner and
//! `restart_m = 0`, so the legacy 35/70-action spaces are unchanged in
//! content, order, and rendering; the grown arms are appended behind
//! them by [`ActionSpace::extended_precond_top_k`] and are opt-in via
//! `Config::precond_arms`.

use crate::chop::Prec;

/// Which refinement engine an action runs (DESIGN.md §2d).
///
/// * `LuIr` — the paper's LU-preconditioned GMRES-IR: O(n³) dense
///   factorization in u_f, inner GMRES in u_g.
/// * `CgIr` — matvec-only Jacobi-preconditioned CG-IR for SPD systems:
///   no factorization, no densification; every operator application is
///   O(nnz) on sparse inputs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SolverFamily {
    LuIr = 0,
    CgIr = 1,
}

impl SolverFamily {
    pub const ALL: [SolverFamily; 2] = [SolverFamily::LuIr, SolverFamily::CgIr];

    /// Stable name used in policy JSON and the CLI `--solver` switch.
    pub fn name(self) -> &'static str {
        match self {
            SolverFamily::LuIr => "lu-ir",
            SolverFamily::CgIr => "cg-ir",
        }
    }

    pub fn by_name(name: &str) -> Option<SolverFamily> {
        SolverFamily::ALL.iter().copied().find(|f| f.name() == name)
    }
}

impl std::fmt::Display for SolverFamily {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Preconditioner choice for the inner solver (schema v3, PEARL axis).
///
/// The discriminant order is the policy-JSON / hash encoding order and
/// must stay stable. `None` and `Jacobi` are the historical implicit
/// choices of the LU and CG families respectively (the LU family's
/// inner GMRES is already LU-preconditioned; "None" means *no extra*
/// preconditioner), so every pre-v3 action maps onto its family's
/// default and the legacy reward/cost anchors are untouched.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Precond {
    None = 0,
    Jacobi = 1,
    BlockJacobi = 2,
    Ssor = 3,
}

impl Precond {
    pub const ALL: [Precond; 4] = [
        Precond::None,
        Precond::Jacobi,
        Precond::BlockJacobi,
        Precond::Ssor,
    ];

    /// Stable name used in policy JSON and the CLI `--precond` switch.
    pub fn name(self) -> &'static str {
        match self {
            Precond::None => "none",
            Precond::Jacobi => "jacobi",
            Precond::BlockJacobi => "block-jacobi",
            Precond::Ssor => "ssor",
        }
    }

    pub fn by_name(name: &str) -> Option<Precond> {
        Precond::ALL.iter().copied().find(|p| p.name() == name)
    }

    /// The historical implicit preconditioner of each family: pre-v3
    /// actions deserialize to this, and [`Action::with_solver`] resets
    /// to it so family-mirrored spaces stay well-formed.
    pub fn default_for(f: SolverFamily) -> Precond {
        match f {
            SolverFamily::LuIr => Precond::None,
            SolverFamily::CgIr => Precond::Jacobi,
        }
    }
}

impl std::fmt::Display for Precond {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// A (solver family, precision configuration) pair for one solve.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Action {
    /// which refinement engine runs the solve
    pub solver: SolverFamily,
    /// u_f — LU factorization + initial solve (LU) / preconditioner
    /// build + diagonal initial solve (CG)
    pub u_f: Prec,
    /// u — solution update x_{i+1} = x_i + z_i
    pub u: Prec,
    /// u_g — inner-solver working precision (incl. preconditioner
    /// application)
    pub u_g: Prec,
    /// u_r — residual computation
    pub u_r: Prec,
    /// which preconditioner the inner solver applies (v3 dimension;
    /// family default for all pre-v3 actions)
    pub precond: Precond,
    /// GMRES restart length for the LU family's inner solver; 0 keeps
    /// the historical single-cycle inner solve (v3 dimension)
    pub restart_m: usize,
}

impl Action {
    /// The all-FP64 LU/GMRES-IR baseline the paper compares against.
    pub const FP64: Action = Action {
        solver: SolverFamily::LuIr,
        u_f: Prec::Fp64,
        u: Prec::Fp64,
        u_g: Prec::Fp64,
        u_r: Prec::Fp64,
        precond: Precond::None,
        restart_m: 0,
    };

    /// The all-FP64 CG-IR anchor (the CG family's safe configuration).
    pub const CG_FP64: Action = Action {
        solver: SolverFamily::CgIr,
        u_f: Prec::Fp64,
        u: Prec::Fp64,
        u_g: Prec::Fp64,
        u_r: Prec::Fp64,
        precond: Precond::Jacobi,
        restart_m: 0,
    };

    /// LU/GMRES-IR action with the given precisions.
    pub fn lu(u_f: Prec, u: Prec, u_g: Prec, u_r: Prec) -> Action {
        Action {
            solver: SolverFamily::LuIr,
            u_f,
            u,
            u_g,
            u_r,
            precond: Precond::None,
            restart_m: 0,
        }
    }

    /// CG-IR action with the given precisions.
    pub fn cg(u_f: Prec, u: Prec, u_g: Prec, u_r: Prec) -> Action {
        Action {
            solver: SolverFamily::CgIr,
            u_f,
            u,
            u_g,
            u_r,
            precond: Precond::Jacobi,
            restart_m: 0,
        }
    }

    /// The same precision configuration under a different solver family.
    /// The preconditioner resets to the target family's default (a CG
    /// mirror of an LU action is Jacobi-PCG, not "no preconditioner"),
    /// so mirrored spaces contain only well-formed arms.
    pub fn with_solver(mut self, solver: SolverFamily) -> Action {
        self.solver = solver;
        self.precond = Precond::default_for(solver);
        self
    }

    /// The same action with a different preconditioner.
    pub fn with_precond(mut self, precond: Precond) -> Action {
        self.precond = precond;
        self
    }

    /// The same action with a GMRES restart length (0 = single-cycle).
    pub fn with_restart(mut self, restart_m: usize) -> Action {
        self.restart_m = restart_m;
        self
    }

    /// Is every v3 hyperparameter at its family default? True for every
    /// action of the legacy (pre-v3) spaces.
    pub fn is_legacy_shape(&self) -> bool {
        self.precond == Precond::default_for(self.solver) && self.restart_m == 0
    }

    /// The precision tuple in paper order (u_f, u, u_g, u_r).
    pub fn tuple(&self) -> [Prec; 4] {
        [self.u_f, self.u, self.u_g, self.u_r]
    }

    /// Monotone constraint of eq. (11): u_f ≤ u ≤ u_g ≤ u_r by
    /// significand bits (applied per family).
    pub fn is_monotone(&self) -> bool {
        self.u_f <= self.u && self.u <= self.u_g && self.u_g <= self.u_r
    }

    pub fn name(&self) -> String {
        let precs = format!(
            "({},{},{},{})",
            self.u_f.name(),
            self.u.name(),
            self.u_g.name(),
            self.u_r.name()
        );
        let mut s = match self.solver {
            // LU keeps the historical bare-tuple rendering (tables/CSVs
            // stay diffable against earlier runs)
            SolverFamily::LuIr => precs,
            SolverFamily::CgIr => format!("cg{precs}"),
        };
        // v3 hyperparameters render only when non-default, so every
        // legacy arm keeps its historical name byte-for-byte.
        if self.precond != Precond::default_for(self.solver) {
            s.push('+');
            s.push_str(self.precond.name());
        }
        if self.restart_m != 0 {
            s.push_str(&format!("@m{}", self.restart_m));
        }
        s
    }
}

impl std::fmt::Display for Action {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// An ordered action list: the per-family reduced space 𝒜_reduced, the
/// two-family extended space, or any pruned subset (a policy's Q-table
/// carries the exact list it was trained over).
#[derive(Clone, Debug)]
pub struct ActionSpace {
    pub actions: Vec<Action>,
}

impl ActionSpace {
    /// All m^k joint LU-family actions (k=4 fixed by GMRES-IR).
    pub fn full() -> ActionSpace {
        let mut actions = Vec::new();
        for &u_f in &Prec::ALL {
            for &u in &Prec::ALL {
                for &u_g in &Prec::ALL {
                    for &u_r in &Prec::ALL {
                        actions.push(Action::lu(u_f, u, u_g, u_r));
                    }
                }
            }
        }
        ActionSpace { actions }
    }

    /// The monotone reduction of eq. (11) for the LU family:
    /// non-decreasing tuples only.
    pub fn reduced() -> ActionSpace {
        let mut actions: Vec<Action> = ActionSpace::full()
            .actions
            .into_iter()
            .filter(Action::is_monotone)
            .collect();
        // Deterministic order: lexicographic by (u_f, u, u_g, u_r),
        // i.e. cheapest-first; ties in Q resolve toward lower precision.
        actions.sort_by_key(|a| a.tuple().map(|p| p as u8));
        ActionSpace { actions }
    }

    /// Optional top-k pruning (§5: "further pruned ... one-fourth of the
    /// valid precision combinations"). Keeps a spread across the cost
    /// spectrum: every ceil(len/k)-th action of the cost-ordered list,
    /// always retaining the all-FP64 fallback.
    pub fn reduced_top_k(k_top: usize) -> ActionSpace {
        let all = ActionSpace::reduced();
        if k_top == 0 || k_top >= all.len() {
            return all;
        }
        let stride = (all.len() as f64 / k_top as f64).ceil() as usize;
        let mut actions: Vec<Action> = all.actions.iter().copied().step_by(stride).collect();
        if !actions.contains(&Action::FP64) {
            actions.push(Action::FP64);
        }
        ActionSpace { actions }
    }

    /// The two-family extended space: the LU reduced list followed by the
    /// same precision tuples under the CG family (70 actions unpruned).
    /// Family-major order keeps the LU block's indices identical to
    /// [`ActionSpace::reduced`], and the Q-table tie-break ("lowest
    /// index wins") therefore still resolves toward cheap LU configs
    /// when a state has no evidence either way.
    pub fn extended() -> ActionSpace {
        ActionSpace::extended_top_k(0)
    }

    /// Pruned extended space: [`ActionSpace::reduced_top_k`] per family,
    /// so both the LU all-FP64 fallback and the CG all-FP64 anchor
    /// survive pruning.
    pub fn extended_top_k(k_top: usize) -> ActionSpace {
        let lu = ActionSpace::reduced_top_k(k_top);
        let mut actions = lu.actions.clone();
        actions.extend(
            lu.actions
                .iter()
                .map(|a| a.with_solver(SolverFamily::CgIr)),
        );
        ActionSpace { actions }
    }

    /// GMRES restart lengths offered as arms by
    /// [`ActionSpace::extended_precond_top_k`]. Short restarts bound the
    /// Arnoldi basis (memory + orthogonalization cost) at the price of
    /// extra cycles; the bandit learns whether that trade pays per
    /// context.
    pub const RESTART_CHOICES: [usize; 2] = [8, 16];

    /// The v3 grown space (opt-in via `Config::precond_arms`): the
    /// pruned extended space followed by
    ///
    /// * CG arms with a stronger-than-Jacobi preconditioner
    ///   (block-Jacobi and SSOR, each at the all-FP64 anchor and one
    ///   mixed tuple), and
    /// * LU arms with a restarted inner GMRES (each `RESTART_CHOICES`
    ///   length at the all-FP64 anchor and the flagship bf16-factor
    ///   tuple).
    ///
    /// Appending after the base keeps every legacy index — and thus the
    /// Q-table tie-break order — identical to [`ActionSpace::extended_top_k`].
    pub fn extended_precond_top_k(k_top: usize) -> ActionSpace {
        let mut actions = ActionSpace::extended_top_k(k_top).actions;
        for pc in [Precond::BlockJacobi, Precond::Ssor] {
            actions.push(Action::CG_FP64.with_precond(pc));
            actions.push(
                Action::cg(Prec::Fp32, Prec::Fp64, Prec::Fp64, Prec::Fp64).with_precond(pc),
            );
        }
        for m in ActionSpace::RESTART_CHOICES {
            actions.push(Action::FP64.with_restart(m));
            actions.push(
                Action::lu(Prec::Bf16, Prec::Fp64, Prec::Fp64, Prec::Fp64).with_restart(m),
            );
        }
        ActionSpace { actions }
    }

    pub fn len(&self) -> usize {
        self.actions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.actions.is_empty()
    }

    pub fn index_of(&self, a: &Action) -> Option<usize> {
        self.actions.iter().position(|x| x == a)
    }

    /// Does the list contain any action of the given family?
    pub fn has_family(&self, f: SolverFamily) -> bool {
        self.actions.iter().any(|a| a.solver == f)
    }

    /// C(m+k−1, k) — the reduced-space cardinality formula (eq. 12).
    pub fn reduced_cardinality(m: usize, k: usize) -> usize {
        // binomial(m+k-1, k) with small arguments
        let n = m + k - 1;
        let mut num: u128 = 1;
        let mut den: u128 = 1;
        for i in 0..k {
            num *= (n - i) as u128;
            den *= (i + 1) as u128;
        }
        (num / den) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_space_has_256_actions() {
        assert_eq!(ActionSpace::full().len(), 256); // m^k = 4^4 (eq. 1)
    }

    #[test]
    fn reduced_space_has_35_actions() {
        // §3.2: "we prune the action space from 256 to 35, ~86%"
        let r = ActionSpace::reduced();
        assert_eq!(r.len(), 35);
        assert_eq!(ActionSpace::reduced_cardinality(4, 4), 35);
        let cut = 1.0 - 35.0 / 256.0;
        assert!(cut > 0.86 && cut < 0.87);
        // the reduced space is the LU family only
        assert!(r.has_family(SolverFamily::LuIr));
        assert!(!r.has_family(SolverFamily::CgIr));
    }

    #[test]
    fn extended_space_doubles_reduced_and_keeps_lu_prefix() {
        let r = ActionSpace::reduced();
        let e = ActionSpace::extended();
        assert_eq!(e.len(), 70);
        // LU block first, indices unchanged vs reduced()
        for (i, a) in r.actions.iter().enumerate() {
            assert_eq!(&e.actions[i], a, "index {i}");
        }
        // CG block mirrors the tuples
        for (i, a) in r.actions.iter().enumerate() {
            let c = &e.actions[r.len() + i];
            assert_eq!(c.solver, SolverFamily::CgIr);
            assert_eq!(c.tuple(), a.tuple());
        }
        assert!(e.index_of(&Action::FP64).is_some());
        assert!(e.index_of(&Action::CG_FP64).is_some());
    }

    #[test]
    fn extended_top_k_keeps_both_fp64_anchors() {
        let e = ActionSpace::extended_top_k(9);
        assert_eq!(e.len(), 2 * ActionSpace::reduced_top_k(9).len());
        assert!(e.index_of(&Action::FP64).is_some());
        assert!(e.index_of(&Action::CG_FP64).is_some());
        assert!(e.has_family(SolverFamily::CgIr));
        // no duplicates
        let mut set = std::collections::HashSet::new();
        for a in &e.actions {
            assert!(set.insert(*a), "duplicate {a}");
        }
    }

    #[test]
    fn reduced_cardinality_formula() {
        assert_eq!(ActionSpace::reduced_cardinality(2, 2), 3);
        assert_eq!(ActionSpace::reduced_cardinality(3, 2), 6);
        assert_eq!(ActionSpace::reduced_cardinality(7, 4), 210);
    }

    #[test]
    fn all_reduced_actions_are_monotone_and_unique() {
        let r = ActionSpace::reduced();
        for a in &r.actions {
            assert!(a.is_monotone(), "{a}");
        }
        let mut set = std::collections::HashSet::new();
        for a in &r.actions {
            assert!(set.insert(*a), "duplicate {a}");
        }
    }

    #[test]
    fn reduced_contains_extremes() {
        let r = ActionSpace::reduced();
        assert!(r.index_of(&Action::FP64).is_some());
        let all_bf16 = Action::lu(Prec::Bf16, Prec::Bf16, Prec::Bf16, Prec::Bf16);
        assert!(r.index_of(&all_bf16).is_some());
        // the paper's flagship mixed config: low factorization, high residual
        let flagship = Action::lu(Prec::Bf16, Prec::Fp64, Prec::Fp64, Prec::Fp64);
        assert!(r.index_of(&flagship).is_some());
    }

    #[test]
    fn non_monotone_rejected() {
        let bad = Action::lu(Prec::Fp64, Prec::Bf16, Prec::Fp64, Prec::Fp64);
        assert!(!bad.is_monotone());
        assert!(ActionSpace::reduced().index_of(&bad).is_none());
    }

    #[test]
    fn top_k_pruning_keeps_fp64_and_spread() {
        // §5: one-fourth of the 35 valid combinations
        let pruned = ActionSpace::reduced_top_k(9);
        assert!(pruned.len() <= 10 && pruned.len() >= 8, "{}", pruned.len());
        assert!(pruned.index_of(&Action::FP64).is_some());
        // includes at least one low-precision action
        assert!(pruned.actions.iter().any(|a| a.u_f == Prec::Bf16));
        // k_top = 0 disables pruning
        assert_eq!(ActionSpace::reduced_top_k(0).len(), 35);
        assert_eq!(ActionSpace::reduced_top_k(100).len(), 35);
    }

    #[test]
    fn family_names_roundtrip() {
        for f in SolverFamily::ALL {
            assert_eq!(SolverFamily::by_name(f.name()), Some(f));
        }
        assert_eq!(SolverFamily::by_name("qr-ir"), None);
        // action rendering: LU keeps the bare tuple, CG is prefixed
        assert_eq!(Action::FP64.name(), "(fp64,fp64,fp64,fp64)");
        assert_eq!(Action::CG_FP64.name(), "cg(fp64,fp64,fp64,fp64)");
        assert_eq!(Action::FP64.with_solver(SolverFamily::CgIr), Action::CG_FP64);
    }

    #[test]
    fn precond_names_roundtrip_and_defaults() {
        for p in Precond::ALL {
            assert_eq!(Precond::by_name(p.name()), Some(p));
        }
        assert_eq!(Precond::by_name("ilu0"), None);
        assert_eq!(Precond::default_for(SolverFamily::LuIr), Precond::None);
        assert_eq!(Precond::default_for(SolverFamily::CgIr), Precond::Jacobi);
        assert!(Action::FP64.is_legacy_shape());
        assert!(Action::CG_FP64.is_legacy_shape());
        assert!(!Action::CG_FP64.with_precond(Precond::Ssor).is_legacy_shape());
        assert!(!Action::FP64.with_restart(8).is_legacy_shape());
    }

    #[test]
    fn v3_arm_rendering_only_marks_non_defaults() {
        // legacy arms keep their historical names byte-for-byte
        assert_eq!(Action::FP64.name(), "(fp64,fp64,fp64,fp64)");
        assert_eq!(Action::CG_FP64.name(), "cg(fp64,fp64,fp64,fp64)");
        assert_eq!(
            Action::CG_FP64.with_precond(Precond::Ssor).name(),
            "cg(fp64,fp64,fp64,fp64)+ssor"
        );
        assert_eq!(
            Action::FP64.with_restart(16).name(),
            "(fp64,fp64,fp64,fp64)@m16"
        );
        assert_eq!(
            Action::cg(Prec::Fp32, Prec::Fp64, Prec::Fp64, Prec::Fp64)
                .with_precond(Precond::BlockJacobi)
                .name(),
            "cg(fp32,fp64,fp64,fp64)+block-jacobi"
        );
    }

    #[test]
    fn extended_precond_space_appends_after_legacy_block() {
        let base = ActionSpace::extended_top_k(9);
        let grown = ActionSpace::extended_precond_top_k(9);
        assert_eq!(grown.len(), base.len() + 8);
        // legacy indices untouched
        for (i, a) in base.actions.iter().enumerate() {
            assert_eq!(&grown.actions[i], a, "index {i}");
        }
        // grown arms are monotone, unique, and non-legacy
        let mut set = std::collections::HashSet::new();
        for a in &grown.actions {
            assert!(a.is_monotone(), "{a}");
            assert!(set.insert(*a), "duplicate {a}");
        }
        for a in &grown.actions[base.len()..] {
            assert!(!a.is_legacy_shape(), "{a}");
        }
        // both new preconditioners and both restart lengths represented
        for pc in [Precond::BlockJacobi, Precond::Ssor] {
            assert!(grown.actions.iter().any(|a| a.precond == pc));
        }
        for m in ActionSpace::RESTART_CHOICES {
            assert!(grown
                .actions
                .iter()
                .any(|a| a.restart_m == m && a.solver == SolverFamily::LuIr));
        }
    }

    #[test]
    fn property_reduction_matches_formula_for_all_mk() {
        // enumerate non-decreasing tuples for m in 1..=4 (restricting to
        // prefixes of Prec::ALL), k = 4, and compare with the formula
        for m in 1..=4usize {
            let count = ActionSpace::full()
                .actions
                .iter()
                .filter(|a| a.is_monotone())
                .filter(|a| a.tuple().iter().all(|p| (*p as usize) < m))
                .count();
            assert_eq!(count, ActionSpace::reduced_cardinality(m, 4), "m={m}");
        }
    }
}
