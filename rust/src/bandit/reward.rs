//! The multi-objective reward (eq. 21):
//!
//!   R(s_d, a) = w₂ f_precision + w₁ f_accuracy − w₃ f_penalty
//!
//! * f_precision (eq. 22): rewards low-precision steps, discounted by the
//!   system's conditioning — Σ_p w_step · t_FP64 / (t_p (1 + log10 max(κ, 1))).
//!   The per-step weights encode each family's cost model (DESIGN.md
//!   §2d): the LU family keeps the paper's equal weights (the O(n³)
//!   factorization, the O(n²) GMRES matvecs, and the O(n²) residual are
//!   all dense-BLAS bound); the CG family has **no factorization** and
//!   its cost is dominated by the u_g matvecs, so its weights shift onto
//!   the inner-solver slot — (0.5, 0.5, 2.0, 1.0) over (u_f, u, u_g,
//!   u_r), summing to 4 so rewards stay comparable across families.
//! * f_accuracy (eq. 24): −C₁ (min(log10 max(ferr, ε), θ) +
//!   min(log10 max(nbe, ε), θ)) — positive for small errors, truncated at
//!   θ so catastrophic errors don't dominate the scale.
//! * f_penalty (eq. 25): log₂ max(T_iter, 1) with T_iter the total inner
//!   GMRES iterations (§5.4 ablates this term).
//!
//! Solver failure (LU breakdown, non-finite iterates) maps to a flat
//! `fail_reward` — the environment's "this configuration is unusable"
//! signal.

use crate::bandit::action::{Action, Precond, SolverFamily};
use crate::chop::Prec;
use crate::util::config::Config;

/// Everything the reward needs from one solve.
#[derive(Clone, Copy, Debug)]
pub struct RewardInputs {
    pub ferr: f64,
    pub nbe: f64,
    /// total inner GMRES iterations (T_iter of eq. 25)
    pub gmres_iters: usize,
    pub kappa: f64,
    pub failed: bool,
}

/// Per-step cost-model weights over (u_f, u, u_g, u_r) — each family's
/// share of work per slot, normalized to sum to 4 so an all-FP64 action
/// scores 4/(1+log₁₀κ) under either family (cross-family comparability).
pub fn step_weights(family: SolverFamily) -> [f64; 4] {
    match family {
        // equal weights: the paper's eq. 22 as-is
        SolverFamily::LuIr => [1.0, 1.0, 1.0, 1.0],
        // no factorization; u_g matvecs dominate (one per PCG iteration),
        // the residual is one more matvec, u_f/u are O(n) vector work
        SolverFamily::CgIr => [0.5, 0.5, 2.0, 1.0],
    }
}

/// Extra work a preconditioner choice adds on top of the family's
/// 4-unit step budget (DESIGN.md §2i). `None` and `Jacobi` are the
/// historical implicit choices already priced into [`step_weights`], so
/// they cost 0 and every legacy arm's reward is bit-identical to v2.
/// Block-Jacobi pays a one-off block-LU build plus a dense block
/// triangular solve per PCG iteration (~0.75 matvec-equivalents
/// amortized); SSOR pays two sparse triangular sweeps per application —
/// about one full extra matvec per iteration plus setup (~1.25).
pub fn precond_extra_cost(p: Precond) -> f64 {
    match p {
        Precond::None | Precond::Jacobi => 0.0,
        Precond::BlockJacobi => 0.75,
        Precond::Ssor => 1.25,
    }
}

/// f_precision (eq. 22), weighted by the family's cost model and — for
/// v3 preconditioned arms — deflated by the preconditioner's extra
/// work, so a cheap tuple can't hide an expensive preconditioner:
/// scale = 4 / (4 + extra). Restart-m arms carry no static cost term;
/// their economics (fewer orthogonalizations vs more cycles) surface
/// through T_iter in f_penalty.
pub fn f_precision(action: &Action, kappa: f64) -> f64 {
    let t64 = Prec::Fp64.t() as f64;
    let discount = 1.0 + kappa.max(1.0).log10();
    let w = step_weights(action.solver);
    let base: f64 = action
        .tuple()
        .iter()
        .zip(w)
        .map(|(p, wi)| wi * t64 / (p.t() as f64 * discount))
        .sum();
    let extra = precond_extra_cost(action.precond);
    if extra == 0.0 {
        // skip the scale entirely: legacy arms stay bit-identical
        base
    } else {
        base * 4.0 / (4.0 + extra)
    }
}

/// f_accuracy (eq. 24).
pub fn f_accuracy(ferr: f64, nbe: f64, c1: f64, theta: f64, eps: f64) -> f64 {
    let term = |e: f64| (e.max(eps).log10()).min(theta);
    -c1 * (term(ferr) + term(nbe))
}

/// f_penalty (eq. 25).
pub fn f_penalty(gmres_iters: usize) -> f64 {
    (gmres_iters.max(1) as f64).log2()
}

/// Full reward (eq. 21) under the configured weights.
pub fn reward(cfg: &Config, action: &Action, inp: &RewardInputs) -> f64 {
    if inp.failed || !inp.ferr.is_finite() || !inp.nbe.is_finite() {
        return cfg.fail_reward;
    }
    let w = cfg.weights;
    let mut r = w.w2 * f_precision(action, inp.kappa)
        + w.w1 * f_accuracy(inp.ferr, inp.nbe, cfg.c1, cfg.theta, cfg.acc_eps);
    if cfg.penalty_enabled {
        r -= w.w3 * f_penalty(inp.gmres_iters);
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bandit::action::ActionSpace;
    use crate::util::config::Weights;

    fn cfg() -> Config {
        Config::default()
    }

    fn inputs(ferr: f64, nbe: f64, iters: usize, kappa: f64) -> RewardInputs {
        RewardInputs { ferr, nbe, gmres_iters: iters, kappa, failed: false }
    }

    #[test]
    fn f_precision_prefers_low_precision() {
        let all64 = Action::FP64;
        let all16 = Action::lu(Prec::Bf16, Prec::Bf16, Prec::Bf16, Prec::Bf16);
        assert!(f_precision(&all16, 10.0) > f_precision(&all64, 10.0));
        // all-FP64 at kappa=1: 4 * 53/53 / 1 = 4
        assert!((f_precision(&all64, 1.0) - 4.0).abs() < 1e-12);
        // all-bf16 at kappa=1: 4 * 53/8
        assert!((f_precision(&all16, 1.0) - 4.0 * 53.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn cg_cost_model_weights_matvec_slot() {
        // families agree on the all-FP64 anchor ...
        assert!((f_precision(&Action::CG_FP64, 1.0) - 4.0).abs() < 1e-12);
        assert_eq!(step_weights(SolverFamily::LuIr).iter().sum::<f64>(), 4.0);
        assert_eq!(step_weights(SolverFamily::CgIr).iter().sum::<f64>(), 4.0);
        // ... but CG pays (and earns) most through u_g: lowering u_g
        // yields a bigger f_precision gain than lowering u_f, the
        // opposite emphasis of the factorization-dominated LU family.
        let cg_low_g = Action::cg(Prec::Fp64, Prec::Fp64, Prec::Fp64, Prec::Fp64);
        let mut lower_g = cg_low_g;
        lower_g.u_g = Prec::Bf16;
        let mut lower_f = cg_low_g;
        lower_f.u_f = Prec::Bf16;
        let gain_g = f_precision(&lower_g, 1.0) - f_precision(&cg_low_g, 1.0);
        let gain_f = f_precision(&lower_f, 1.0) - f_precision(&cg_low_g, 1.0);
        assert!(gain_g > gain_f, "u_g gain {gain_g} must beat u_f gain {gain_f}");
        // for LU the same comparison is equal-weight
        let lu = Action::FP64;
        let mut lu_g = lu;
        lu_g.u_g = Prec::Bf16;
        let mut lu_f = lu;
        lu_f.u_f = Prec::Bf16;
        assert!((f_precision(&lu_g, 1.0) - f_precision(&lu_f, 1.0)).abs() < 1e-12);
    }

    #[test]
    fn precond_cost_deflates_f_precision_but_not_legacy_arms() {
        // legacy arms (family-default preconditioner): bit-identical to
        // the pre-v3 formula — exact equality, not approximate
        assert_eq!(f_precision(&Action::CG_FP64, 1.0), 4.0);
        assert_eq!(
            f_precision(&Action::CG_FP64.with_precond(Precond::Jacobi), 1.0),
            4.0
        );
        // restart arms carry no static cost term
        assert_eq!(
            f_precision(&Action::FP64.with_restart(8), 1e3),
            f_precision(&Action::FP64, 1e3)
        );
        // stronger preconditioners deflate by 4/(4+extra)
        let bj = f_precision(&Action::CG_FP64.with_precond(Precond::BlockJacobi), 1.0);
        let ssor = f_precision(&Action::CG_FP64.with_precond(Precond::Ssor), 1.0);
        assert!((bj - 4.0 * 4.0 / 4.75).abs() < 1e-12, "{bj}");
        assert!((ssor - 4.0 * 4.0 / 5.25).abs() < 1e-12, "{ssor}");
        assert!(ssor < bj && bj < 4.0);
        // the deflation is uniform over the tuple, so cheap tuples still
        // out-earn expensive ones under the same preconditioner
        let cheap = Action::cg(Prec::Bf16, Prec::Fp64, Prec::Fp64, Prec::Fp64)
            .with_precond(Precond::Ssor);
        assert!(f_precision(&cheap, 1.0) > ssor);
    }

    #[test]
    fn f_precision_discounted_by_conditioning() {
        let a = Action::lu(Prec::Bf16, Prec::Fp32, Prec::Fp64, Prec::Fp64);
        let low = f_precision(&a, 1e2);
        let high = f_precision(&a, 1e8);
        // eq. 22: the (1 + log10 kappa) denominator shrinks the incentive
        // to use low precision on hard systems.
        assert!((low / high - 9.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn f_accuracy_rewards_small_errors_and_truncates() {
        let good = f_accuracy(1e-14, 1e-17, 1.0, 2.5, 1e-10);
        let bad = f_accuracy(1e-2, 1e-4, 1.0, 2.5, 1e-10);
        assert!(good > bad);
        // ε floor: errors below 1e-10 saturate
        assert_eq!(
            f_accuracy(1e-14, 1e-17, 1.0, 2.5, 1e-10),
            f_accuracy(1e-10, 1e-10, 1.0, 2.5, 1e-10)
        );
        // θ ceiling: catastrophic errors are clamped
        assert_eq!(
            f_accuracy(1e10, 1e10, 1.0, 2.5, 1e-10),
            f_accuracy(1e3, 1e3, 1.0, 2.5, 1e-10)
        );
        assert_eq!(f_accuracy(1e10, 1e10, 1.0, 2.5, 1e-10), -5.0);
    }

    #[test]
    fn f_penalty_log2_of_iterations() {
        assert_eq!(f_penalty(0), 0.0);
        assert_eq!(f_penalty(1), 0.0);
        assert_eq!(f_penalty(8), 3.0);
        assert!(f_penalty(20) > f_penalty(10));
    }

    #[test]
    fn failure_gets_flat_penalty() {
        let c = cfg();
        let mut inp = inputs(1e-15, 1e-16, 2, 1e2);
        inp.failed = true;
        assert_eq!(reward(&c, &Action::FP64, &inp), c.fail_reward);
        let nan_inp = inputs(f64::NAN, 1e-16, 2, 1e2);
        assert_eq!(reward(&c, &Action::FP64, &nan_inp), c.fail_reward);
    }

    #[test]
    fn penalty_flag_ablates_term() {
        let mut c = cfg();
        let inp = inputs(1e-12, 1e-15, 16, 1e3);
        let with = reward(&c, &Action::FP64, &inp);
        c.penalty_enabled = false;
        let without = reward(&c, &Action::FP64, &inp);
        // gap = w3 * log2(16) = 0.25 * 4
        assert!((without - with - 1.0).abs() < 1e-12);
    }

    #[test]
    fn w2_increase_shifts_optimum_toward_low_precision() {
        // The W1 vs W2 story of §5.2 at reward level: for a
        // well-conditioned system where low precision costs a bit of
        // accuracy and a few iterations, W2 must rank the cheap action
        // higher than W1 does relative to all-FP64.
        let mut c = cfg();
        let cheap = Action::lu(Prec::Bf16, Prec::Fp64, Prec::Fp64, Prec::Fp64);
        // plausible outcomes at kappa=1e2:
        let cheap_out = inputs(1e-13, 1e-16, 6, 1e2);
        let fp64_out = inputs(1e-15, 1e-17, 2, 1e2);
        c.weights = Weights::W1;
        let d_w1 = reward(&c, &cheap, &cheap_out) - reward(&c, &Action::FP64, &fp64_out);
        c.weights = Weights::W2;
        let d_w2 = reward(&c, &cheap, &cheap_out) - reward(&c, &Action::FP64, &fp64_out);
        assert!(d_w2 > d_w1);
        assert!(d_w2 > 0.0, "W2 should favor the cheap action: {d_w2}");
    }

    #[test]
    fn property_reward_monotone_in_each_error() {
        use crate::util::proptest::{check, gen};
        let c = cfg();
        check("reward_monotone", 13, 300, |rng| {
            // all families and v3 arms: the monotonicity contract is
            // family- and preconditioner-blind
            let space = ActionSpace::extended_precond_top_k(0);
            let a = space.actions[rng.below(space.len())];
            let kappa = 10f64.powf(rng.uniform_in(0.0, 10.0));
            let e1 = 10f64.powf(rng.uniform_in(-16.0, 1.0));
            let e2 = e1 * 10f64.powf(rng.uniform_in(0.1, 3.0));
            let nbe = 10f64.powf(rng.uniform_in(-17.0, -5.0));
            let it = 1 + rng.below(50);
            let r1 = reward(&c, &a, &inputs(e1, nbe, it, kappa));
            let r2 = reward(&c, &a, &inputs(e2, nbe, it, kappa));
            crate::prop_assert!(r1 >= r2, "larger ferr must not pay more: {r1} < {r2}");
            Ok(())
        });
    }
}
