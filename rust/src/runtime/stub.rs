//! Offline stand-in for the PJRT backend (the default build: no `pjrt`
//! feature, no `xla` crate). Every call-site type-checks; `open()` fails
//! with an actionable message, so `--backend pjrt` degrades to a clean
//! runtime error instead of a compile-time hole.

use anyhow::{bail, Result};

use crate::chop::Prec;
use crate::solver::{GmresOutcome, LuHandle, ProblemSession, SolverBackend};

const MSG: &str = "PJRT backend unavailable: this binary was built without the `pjrt` \
cargo feature (the `xla` crate cannot be vendored offline). Rebuild with \
`--features pjrt` on a host with the xla dependency.";

/// Stub runtime: exists so `backend.rt.artifacts_compiled()` call sites
/// compile; unreachable at runtime because [`PjrtBackend::open`] errors.
pub struct PjrtRuntime {
    _private: (),
}

impl PjrtRuntime {
    pub fn open(_dir: &str) -> Result<PjrtRuntime> {
        bail!("{MSG}");
    }

    pub fn artifacts_compiled(&self) -> usize {
        0
    }
}

/// Stub backend mirroring the real `pjrt::PjrtBackend` surface.
pub struct PjrtBackend {
    pub rt: PjrtRuntime,
}

impl PjrtBackend {
    pub fn open(_dir: &str) -> Result<PjrtBackend> {
        bail!("{MSG}");
    }

    /// Mirror of the batch-native many-RHS dispatch (unreachable: the
    /// stub backend never opens).
    pub fn lu_solve_batch(
        &self,
        _f: &LuHandle,
        _bs: &[Vec<f64>],
        _p: Prec,
    ) -> Result<Vec<Vec<f64>>> {
        bail!("{MSG}");
    }

    /// Mirror of the batch-native many-system residual sweep.
    pub fn residual_batch(
        &self,
        _items: &[(&ProblemSession<'_>, &[f64], &[f64])],
        _p: Prec,
    ) -> Result<Vec<Vec<f64>>> {
        bail!("{MSG}");
    }
}

impl SolverBackend for PjrtBackend {
    fn lu_factor(&self, _s: &ProblemSession<'_>, _p: Prec) -> Result<LuHandle> {
        bail!("{MSG}");
    }

    fn lu_solve(&self, _f: &LuHandle, _b: &[f64], _p: Prec) -> Result<Vec<f64>> {
        bail!("{MSG}");
    }

    fn residual(&self, _s: &ProblemSession<'_>, _x: &[f64], _b: &[f64], _p: Prec) -> Result<Vec<f64>> {
        bail!("{MSG}");
    }

    fn gmres(
        &self,
        _s: &ProblemSession<'_>,
        _f: &LuHandle,
        _r: &[f64],
        _tol: f64,
        _max_m: usize,
        _p: Prec,
    ) -> Result<GmresOutcome> {
        bail!("{MSG}");
    }

    fn name(&self) -> &'static str {
        "pjrt-stub"
    }
}
