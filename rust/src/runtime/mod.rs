//! PJRT runtime — the Layer-3 ↔ Layer-2 bridge.
//!
//! Loads the HLO-text artifacts produced by `python/compile/aot.py`
//! (manifest-described, one per (op, format, size-bucket)), compiles them
//! once on the PJRT CPU client (`xla` crate), caches the executables, and
//! exposes them as a [`crate::solver::SolverBackend`]. Matrices whose size
//! falls between buckets are padded block-diagonally with the identity
//! (`A ↦ diag(A, I)`, `b ↦ [b; 0]`), which leaves the solution, the LU
//! block structure and the residual of the original system untouched
//! (see `padding_invariance` tests).
//!
//! Python runs only at `make artifacts` time; this module is the entire
//! request path.
//!
//! The `xla` crate cannot be vendored into the offline build (DESIGN.md
//! §6), so the PJRT client lives behind the `pjrt` cargo feature. Without
//! it, [`PjrtBackend::open`] is a stub that returns an error, keeping the
//! CLI's `--backend pjrt` plumbing compiling everywhere.
//!
//! [`artifact`] holds the xla-free half of ISSUE 10: the versioned
//! solve-plan codec persisted by [`crate::api::PlanStore`], and the
//! [`plan_batches`] grouping policy the PJRT backend uses to dispatch
//! many systems per device call (one executable invocation per
//! (op, size-bucket) group, padded to the bucket).

pub mod artifact;
pub mod manifest;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{
    ivec_literal, literal_scalar_f64, literal_scalar_i32, literal_to_f64s, literal_to_i32s,
    mat_literal, vec_literal, PjrtBackend, PjrtRuntime,
};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{PjrtBackend, PjrtRuntime};

pub use artifact::{
    plan_batches, plan_file_name, ArtifactError, BatchGroup, LuPayload, PlanArtifact,
};
pub use manifest::{ArtifactMeta, Manifest};

use crate::linalg::Mat;

// ---------------------------------------------------------------------------
// padding (xla-free; shared by both runtime flavors and their tests)
// ---------------------------------------------------------------------------

/// A ↦ diag(A, I_{nb-n}) — preserves the leading block's solution and
/// keeps LU pivoting inside blocks.
pub fn pad_matrix(a: &Mat, nb: usize) -> Mat {
    assert!(nb >= a.n_rows && a.n_rows == a.n_cols);
    if nb == a.n_rows {
        return a.clone();
    }
    let n = a.n_rows;
    let mut p = Mat::zeros(nb, nb);
    for i in 0..n {
        p.row_mut(i)[..n].copy_from_slice(a.row(i));
    }
    for i in n..nb {
        p[(i, i)] = 1.0;
    }
    p
}

/// v ↦ [v; 0].
pub fn pad_vec(v: &[f64], nb: usize) -> Vec<f64> {
    let mut p = v.to_vec();
    p.resize(nb, 0.0);
    p
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn padding_invariance() {
        // diag(A, I) leaves the leading-block solution intact.
        let a = Mat::from_rows(&[&[4.0, 1.0], &[1.0, 3.0]]);
        let p = pad_matrix(&a, 5);
        assert_eq!(p.n_rows, 5);
        assert_eq!(p[(0, 0)], 4.0);
        assert_eq!(p[(4, 4)], 1.0);
        assert_eq!(p[(0, 3)], 0.0);
        let f = crate::linalg::lu::lu_factor(&p).unwrap();
        let b = pad_vec(&[6.0, 5.0], 5);
        let x = f.solve(&b);
        // 4x+y=6, x+3y=5  =>  x = 13/11, y = 14/11
        assert!((x[0] - 13.0 / 11.0).abs() < 1e-12);
        assert!((x[1] - 14.0 / 11.0).abs() < 1e-12);
        assert_eq!(&x[2..], &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn pad_norms_unchanged_for_dominant_blocks() {
        let mut a = Mat::eye(3);
        a[(0, 1)] = 2.0; // ||A||_inf = 3 > 1
        let p = pad_matrix(&a, 8);
        assert_eq!(a.norm_inf(), p.norm_inf());
    }

    #[test]
    fn pad_vec_extends_with_zeros() {
        assert_eq!(pad_vec(&[1.0, 2.0], 4), vec![1.0, 2.0, 0.0, 0.0]);
        assert_eq!(pad_vec(&[1.0], 1), vec![1.0]);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_backend_reports_missing_feature() {
        let err = match PjrtBackend::open("artifacts") {
            Err(e) => e,
            Ok(_) => panic!("stub backend must not open"),
        };
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
