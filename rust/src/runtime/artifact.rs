//! Versioned solve-plan artifacts (ISSUE 10 tentpole): the on-disk
//! codec under [`crate::api::PlanStore`], plus the xla-free batch
//! planner the PJRT backend uses for many-system dispatch.
//!
//! One artifact file persists everything a [`crate::api::SessionEntry`]
//! cannot cheaply recompute for one operator: the operand bytes
//! themselves (dense or CSR, bit-exact — they double as the
//! verify-on-load witness for `same_system`) and the O(n³) feature pass
//! (κ₁ estimate + f64 LU factors). Cheap derived state — chopped-A
//! slabs, chopped-CSR values, preconditioner blocks — is *re-derived*
//! on load: chopping is a deterministic pure function of the operand
//! bits, so rebuilding it is bit-identical by construction and the
//! artifact cannot go stale against it. Section tags for those payloads
//! are reserved below for when the session grows a seeding seam.
//!
//! Layout (all integers little-endian, floats as IEEE-754 bit patterns):
//!
//! ```text
//! magic   [8]  b"PAPLAN01"
//! schema  u32  PLAN_SCHEMA
//! ashash  u64  action-space hash (provenance; 0 = policy-free builder)
//! builder u32 len + utf-8 bytes (provenance, e.g. "precision-autotune 0.1.0")
//! fprint  4 × u64  operator fingerprint (SystemInput::fingerprint)
//! nsec    u32
//! section × nsec: tag u32, len u64, body [len]
//! check   u64  FNV-1a over every preceding byte
//! ```
//!
//! **Reject loudly, never trust:** [`PlanArtifact::decode`] returns a
//! typed [`ArtifactError`] on any defect — truncation, checksum or
//! schema mismatch, malformed sections, non-finite or structurally
//! invalid operands, a fingerprint that does not match the payload.
//! Every allocation while decoding is bounded by the declared section
//! length, which is itself bounded by the bytes actually present, so a
//! mutated length field can never balloon memory (fuzzed in
//! `fuzz/fuzz_plan.rs`).

use anyhow::{bail, Result};

use crate::chop::Prec;
use crate::linalg::Mat;
use crate::sparse::Csr;
use crate::system::SystemInput;

/// File magic: identifies a solve-plan artifact (and its byte order).
pub const PLAN_MAGIC: [u8; 8] = *b"PAPLAN01";

/// Artifact schema version. Bump on any layout change; decode rejects
/// every other version (a plan is a cache, rebuilds are always safe).
pub const PLAN_SCHEMA: u32 = 1;

/// File extension for plan artifacts inside a plan directory.
pub const PLAN_EXT: &str = "plan";

// Section tags (schema 1). Unknown tags are malformed, not skipped:
// within one schema version the section table is closed, and schema
// bumps are cheap because artifacts are a cache.
const SEC_DENSE: u32 = 1;
const SEC_CSR: u32 = 2;
const SEC_FEATURES: u32 = 3;
/// Reserved: pre-chopped operand slabs (re-derived today; see module docs).
pub const SEC_CHOPPED: u32 = 4;
/// Reserved: block-Jacobi / SSOR preconditioner blocks (re-derived today).
pub const SEC_PRECOND: u32 = 5;

/// Typed rejection from the artifact loader. Every variant renders with
/// a stable `plan-artifact[<code>]` prefix so daemon logs and chaos
/// tallies can classify rejections without string-matching prose.
#[derive(Clone, Debug, PartialEq)]
pub enum ArtifactError {
    Truncated { need: usize, have: usize },
    BadMagic,
    SchemaMismatch { found: u32, want: u32 },
    ChecksumMismatch { stored: u64, computed: u64 },
    /// Provenance mismatch (action-space hash / builder) — the artifact
    /// decodes cleanly but was built by an incompatible configuration.
    Stale(&'static str),
    Malformed(&'static str),
}

impl std::fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ArtifactError::Truncated { need, have } => {
                write!(f, "plan-artifact[truncated]: need {need} more bytes, have {have}")
            }
            ArtifactError::BadMagic => {
                write!(f, "plan-artifact[bad-magic]: not a solve-plan artifact")
            }
            ArtifactError::SchemaMismatch { found, want } => {
                write!(f, "plan-artifact[schema]: found v{found}, want v{want}")
            }
            ArtifactError::ChecksumMismatch { stored, computed } => write!(
                f,
                "plan-artifact[checksum]: stored {stored:#018x}, computed {computed:#018x}"
            ),
            ArtifactError::Stale(what) => write!(f, "plan-artifact[stale]: {what}"),
            ArtifactError::Malformed(what) => write!(f, "plan-artifact[malformed]: {what}"),
        }
    }
}

impl std::error::Error for ArtifactError {}

/// FNV-1a over `bytes` — the artifact trailer checksum (same family as
/// `SystemInput::fingerprint`, single lane).
pub fn checksum(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Stable artifact file name for an operator fingerprint.
pub fn plan_file_name(fp: &[u64; 4]) -> String {
    format!("plan_{:016x}{:016x}{:016x}{:016x}.{PLAN_EXT}", fp[0], fp[1], fp[2], fp[3])
}

/// Serialized f64 LU factors (the expensive half of the feature pass).
#[derive(Clone, Debug)]
pub struct LuPayload {
    pub lu: Mat,
    pub piv: Vec<i32>,
    pub prec: Prec,
}

/// One decoded (or to-be-encoded) solve-plan artifact.
#[derive(Clone, Debug)]
pub struct PlanArtifact {
    /// Provenance: hash of the builder's action space (0 = policy-free).
    pub action_space_hash: u64,
    /// Provenance: human-readable builder fingerprint.
    pub builder: String,
    /// Operator fingerprint — always consistent with `system` (enforced
    /// at construction and re-verified on decode).
    pub fingerprint: [u64; 4],
    pub system: SystemInput,
    /// (κ₁ bits, optional f64 LU) — `None` when the source entry never
    /// ran its feature pass (the operand alone is still worth keeping).
    pub features: Option<(f64, Option<LuPayload>)>,
}

// --- encode helpers --------------------------------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    put_u64(out, v.to_bits());
}

fn put_section(out: &mut Vec<u8>, tag: u32, body: &[u8]) {
    put_u32(out, tag);
    put_u64(out, body.len() as u64);
    out.extend_from_slice(body);
}

// --- decode helpers --------------------------------------------------------

struct Cursor<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(b: &'a [u8]) -> Cursor<'a> {
        Cursor { b, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], ArtifactError> {
        let have = self.b.len() - self.pos;
        if have < n {
            return Err(ArtifactError::Truncated { need: n - have, have });
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, ArtifactError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, ArtifactError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, ArtifactError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn i32(&mut self) -> Result<i32, ArtifactError> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, ArtifactError> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn len_usize(&mut self) -> Result<usize, ArtifactError> {
        usize::try_from(self.u64()?)
            .map_err(|_| ArtifactError::Malformed("length field overflows usize"))
    }

    fn done(&self) -> bool {
        self.pos == self.b.len()
    }
}

fn finite_f64s(cur: &mut Cursor<'_>, n: usize, what: &'static str) -> Result<Vec<f64>, ArtifactError> {
    let mut v = Vec::with_capacity(n);
    for _ in 0..n {
        let x = cur.f64()?;
        if !x.is_finite() {
            return Err(ArtifactError::Malformed(what));
        }
        v.push(x);
    }
    Ok(v)
}

fn decode_dense(body: &[u8]) -> Result<Mat, ArtifactError> {
    let mut cur = Cursor::new(body);
    let n_rows = cur.len_usize()?;
    let n_cols = cur.len_usize()?;
    if n_rows == 0 || n_rows != n_cols {
        return Err(ArtifactError::Malformed("dense operand is not square and non-empty"));
    }
    let count = n_rows
        .checked_mul(n_cols)
        .ok_or(ArtifactError::Malformed("dense operand dimensions overflow"))?;
    let data = finite_f64s(&mut cur, count, "non-finite dense operand value")?;
    if !cur.done() {
        return Err(ArtifactError::Malformed("trailing bytes in dense section"));
    }
    Ok(Mat { n_rows, n_cols, data })
}

fn decode_csr(body: &[u8]) -> Result<Csr, ArtifactError> {
    let mut cur = Cursor::new(body);
    let n_rows = cur.len_usize()?;
    let n_cols = cur.len_usize()?;
    let nnz = cur.len_usize()?;
    if n_rows == 0 || n_rows != n_cols {
        return Err(ArtifactError::Malformed("CSR operand is not square and non-empty"));
    }
    let mut row_ptr = Vec::with_capacity(n_rows + 1);
    for _ in 0..=n_rows {
        row_ptr.push(cur.len_usize()?);
    }
    if row_ptr[0] != 0
        || row_ptr[n_rows] != nnz
        || row_ptr.windows(2).any(|w| w[0] > w[1])
    {
        return Err(ArtifactError::Malformed("CSR row_ptr is not a valid prefix scan"));
    }
    let mut col_idx = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        let c = cur.len_usize()?;
        if c >= n_cols {
            return Err(ArtifactError::Malformed("CSR column index out of range"));
        }
        col_idx.push(c);
    }
    let values = finite_f64s(&mut cur, nnz, "non-finite CSR operand value")?;
    if !cur.done() {
        return Err(ArtifactError::Malformed("trailing bytes in CSR section"));
    }
    Ok(Csr { n_rows, n_cols, row_ptr, col_idx, values })
}

fn decode_features(
    body: &[u8],
    operand_n: usize,
) -> Result<(f64, Option<LuPayload>), ArtifactError> {
    let mut cur = Cursor::new(body);
    let kappa = cur.f64()?;
    if kappa.is_nan() {
        return Err(ArtifactError::Malformed("NaN κ₁ estimate"));
    }
    let lu = match cur.u8()? {
        0 => None,
        1 => {
            let n = cur.len_usize()?;
            if n != operand_n {
                return Err(ArtifactError::Malformed("LU dimension does not match operand"));
            }
            let count = n
                .checked_mul(n)
                .ok_or(ArtifactError::Malformed("LU dimensions overflow"))?;
            let data = finite_f64s(&mut cur, count, "non-finite LU value")?;
            let mut piv = Vec::with_capacity(n);
            for _ in 0..n {
                let p = cur.i32()?;
                if p < 0 || p as usize >= n {
                    return Err(ArtifactError::Malformed("LU pivot index out of range"));
                }
                piv.push(p);
            }
            let prec_idx = cur.u8()? as usize;
            if prec_idx >= Prec::ALL.len() {
                return Err(ArtifactError::Malformed("unknown precision tag"));
            }
            Some(LuPayload {
                lu: Mat { n_rows: n, n_cols: n, data },
                piv,
                prec: Prec::from_index(prec_idx),
            })
        }
        _ => return Err(ArtifactError::Malformed("bad LU presence flag")),
    };
    if !cur.done() {
        return Err(ArtifactError::Malformed("trailing bytes in features section"));
    }
    Ok((kappa, lu))
}

impl PlanArtifact {
    /// Build an artifact for `system` (the fingerprint is derived, so
    /// the two can never disagree on the write path).
    pub fn new(
        system: SystemInput,
        action_space_hash: u64,
        builder: String,
        features: Option<(f64, Option<LuPayload>)>,
    ) -> PlanArtifact {
        let fingerprint = system.fingerprint();
        PlanArtifact { action_space_hash, builder, fingerprint, system, features }
    }

    /// Serialize to the schema-1 byte layout (module docs).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&PLAN_MAGIC);
        put_u32(&mut out, PLAN_SCHEMA);
        put_u64(&mut out, self.action_space_hash);
        put_u32(&mut out, self.builder.len() as u32);
        out.extend_from_slice(self.builder.as_bytes());
        for &w in &self.fingerprint {
            put_u64(&mut out, w);
        }
        let n_sections = 1 + self.features.is_some() as u32;
        put_u32(&mut out, n_sections);
        let mut body = Vec::new();
        match &self.system {
            SystemInput::Dense(m) => {
                put_u64(&mut body, m.n_rows as u64);
                put_u64(&mut body, m.n_cols as u64);
                for &x in &m.data {
                    put_f64(&mut body, x);
                }
                put_section(&mut out, SEC_DENSE, &body);
            }
            SystemInput::Sparse(c) => {
                put_u64(&mut body, c.n_rows as u64);
                put_u64(&mut body, c.n_cols as u64);
                put_u64(&mut body, c.values.len() as u64);
                for &p in &c.row_ptr {
                    put_u64(&mut body, p as u64);
                }
                for &j in &c.col_idx {
                    put_u64(&mut body, j as u64);
                }
                for &x in &c.values {
                    put_f64(&mut body, x);
                }
                put_section(&mut out, SEC_CSR, &body);
            }
        }
        if let Some((kappa, lu)) = &self.features {
            let mut body = Vec::new();
            put_f64(&mut body, *kappa);
            match lu {
                None => body.push(0),
                Some(p) => {
                    body.push(1);
                    put_u64(&mut body, p.lu.n_rows as u64);
                    for &x in &p.lu.data {
                        put_f64(&mut body, x);
                    }
                    for &k in &p.piv {
                        body.extend_from_slice(&k.to_le_bytes());
                    }
                    body.push(p.prec as u8);
                }
            }
            put_section(&mut out, SEC_FEATURES, &body);
        }
        let check = checksum(&out);
        put_u64(&mut out, check);
        out
    }

    /// Parse and fully validate an artifact. Any defect is a typed
    /// [`ArtifactError`]; a returned artifact is internally consistent
    /// (checksum, schema, operand structure and finiteness, fingerprint
    /// ↔ payload agreement) and safe to promote into the session cache.
    pub fn decode(bytes: &[u8]) -> Result<PlanArtifact, ArtifactError> {
        if bytes.len() < 8 {
            return Err(ArtifactError::Truncated { need: 8 - bytes.len(), have: bytes.len() });
        }
        let (body, trailer) = bytes.split_at(bytes.len() - 8);
        let stored = u64::from_le_bytes(trailer.try_into().unwrap());
        let computed = checksum(body);
        if stored != computed {
            return Err(ArtifactError::ChecksumMismatch { stored, computed });
        }
        let mut cur = Cursor::new(body);
        if cur.take(8)? != PLAN_MAGIC {
            return Err(ArtifactError::BadMagic);
        }
        let schema = cur.u32()?;
        if schema != PLAN_SCHEMA {
            return Err(ArtifactError::SchemaMismatch { found: schema, want: PLAN_SCHEMA });
        }
        let action_space_hash = cur.u64()?;
        let builder_len = cur.u32()? as usize;
        let builder = std::str::from_utf8(cur.take(builder_len)?)
            .map_err(|_| ArtifactError::Malformed("builder fingerprint is not utf-8"))?
            .to_string();
        let mut fingerprint = [0u64; 4];
        for w in &mut fingerprint {
            *w = cur.u64()?;
        }
        let n_sections = cur.u32()?;
        let mut system: Option<SystemInput> = None;
        let mut features: Option<(f64, Option<LuPayload>)> = None;
        for _ in 0..n_sections {
            let tag = cur.u32()?;
            let len = cur.len_usize()?;
            let body = cur.take(len)?;
            match tag {
                SEC_DENSE | SEC_CSR => {
                    if system.is_some() {
                        return Err(ArtifactError::Malformed("duplicate operand section"));
                    }
                    system = Some(if tag == SEC_DENSE {
                        SystemInput::Dense(decode_dense(body)?)
                    } else {
                        SystemInput::Sparse(decode_csr(body)?)
                    });
                }
                SEC_FEATURES => {
                    if features.is_some() {
                        return Err(ArtifactError::Malformed("duplicate features section"));
                    }
                    let n = match &system {
                        Some(s) => s.n_rows(),
                        None => {
                            return Err(ArtifactError::Malformed(
                                "features section precedes operand section",
                            ))
                        }
                    };
                    features = Some(decode_features(body, n)?);
                }
                _ => return Err(ArtifactError::Malformed("unknown section tag")),
            }
        }
        if !cur.done() {
            return Err(ArtifactError::Malformed("trailing bytes after sections"));
        }
        let system = system.ok_or(ArtifactError::Malformed("missing operand section"))?;
        if system.fingerprint() != fingerprint {
            return Err(ArtifactError::Malformed("fingerprint does not match operand payload"));
        }
        Ok(PlanArtifact { action_space_hash, builder, fingerprint, system, features })
    }
}

// ---------------------------------------------------------------------------
// batch planner (xla-free; the PJRT backend's grouping policy, testable
// without the feature)
// ---------------------------------------------------------------------------

/// One device dispatch: every item in `items` (indices into the caller's
/// work list) runs through the same `(op, bucket)` executable, padded to
/// `bucket`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BatchGroup {
    pub op: String,
    pub bucket: usize,
    pub items: Vec<usize>,
}

/// Smallest manifest bucket that fits `n` (`None` when nothing does).
pub fn bucket_for(buckets: &[usize], n: usize) -> Option<usize> {
    buckets.iter().copied().filter(|&b| b >= n).min()
}

/// Group `(op, n)` work items into per-`(op, bucket)` dispatch groups:
/// one executable invocation per group instead of one per item. Groups
/// come out in first-appearance order, items in submission order, so
/// dispatch is deterministic. Fails if any item exceeds every bucket.
pub fn plan_batches(items: &[(&str, usize)], buckets: &[usize]) -> Result<Vec<BatchGroup>> {
    let mut groups: Vec<BatchGroup> = Vec::new();
    for (i, &(op, n)) in items.iter().enumerate() {
        let Some(bucket) = bucket_for(buckets, n) else {
            bail!(
                "no manifest bucket fits n={n} for op {op} (largest bucket: {})",
                buckets.iter().copied().max().unwrap_or(0)
            );
        };
        match groups.iter_mut().find(|g| g.op == op && g.bucket == bucket) {
            Some(g) => g.items.push(i),
            None => groups.push(BatchGroup { op: op.to_string(), bucket, items: vec![i] }),
        }
    }
    Ok(groups)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn dense_sys(seed: u64, n: usize) -> SystemInput {
        let mut rng = Rng::new(seed);
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                a[(i, j)] = rng.gauss() + if i == j { n as f64 } else { 0.0 };
            }
        }
        SystemInput::Dense(a)
    }

    fn sample_with_lu(seed: u64, n: usize) -> PlanArtifact {
        let system = dense_sys(seed, n);
        let dense = match &system {
            SystemInput::Dense(m) => m.clone(),
            _ => unreachable!(),
        };
        let f = crate::linalg::lu::lu_factor(&dense).unwrap();
        let payload = LuPayload {
            lu: (*f.lu).clone(),
            piv: f.piv.iter().map(|&p| p as i32).collect(),
            prec: Prec::Fp64,
        };
        PlanArtifact::new(system, 0x5eed, "test-builder 0".to_string(), Some((12.5, Some(payload))))
    }

    #[test]
    fn dense_round_trip_is_bitwise() {
        let art = sample_with_lu(1, 6);
        let back = PlanArtifact::decode(&art.encode()).unwrap();
        assert_eq!(back.action_space_hash, 0x5eed);
        assert_eq!(back.builder, "test-builder 0");
        assert_eq!(back.fingerprint, art.fingerprint);
        assert!(crate::api::same_system(&back.system, &art.system));
        let (k0, lu0) = art.features.as_ref().unwrap();
        let (k1, lu1) = back.features.as_ref().unwrap();
        assert_eq!(k0.to_bits(), k1.to_bits());
        let (lu0, lu1) = (lu0.as_ref().unwrap(), lu1.as_ref().unwrap());
        assert_eq!(lu0.piv, lu1.piv);
        assert_eq!(lu0.prec, lu1.prec);
        assert!(lu0.lu.data.iter().zip(&lu1.lu.data).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn csr_round_trip_without_features() {
        let mut rng = Rng::new(9);
        let c = crate::gen::sparse_spd(20, 0.2, 1.0, &mut rng);
        let art = PlanArtifact::new(SystemInput::Sparse(c), 0, "b".to_string(), None);
        let back = PlanArtifact::decode(&art.encode()).unwrap();
        assert!(back.features.is_none());
        assert!(crate::api::same_system(&back.system, &art.system));
    }

    #[test]
    fn every_truncation_is_rejected() {
        let bytes = sample_with_lu(2, 5).encode();
        for k in 0..bytes.len() {
            assert!(PlanArtifact::decode(&bytes[..k]).is_err(), "prefix of {k} bytes accepted");
        }
    }

    #[test]
    fn every_single_bitflip_is_rejected() {
        let bytes = sample_with_lu(3, 4).encode();
        for k in 0..bytes.len() {
            let mut m = bytes.clone();
            m[k] ^= 1;
            let err = PlanArtifact::decode(&m).expect_err("bit flip accepted");
            assert!(err.to_string().starts_with("plan-artifact["), "{err}");
        }
    }

    #[test]
    fn magic_and_schema_mismatches_are_typed() {
        let mut bytes = sample_with_lu(4, 4).encode();
        bytes[0] = b'X';
        let fixed = {
            let n = bytes.len();
            let c = checksum(&bytes[..n - 8]);
            bytes[n - 8..].copy_from_slice(&c.to_le_bytes());
            bytes
        };
        assert_eq!(PlanArtifact::decode(&fixed), Err(ArtifactError::BadMagic));
        let mut bytes = sample_with_lu(4, 4).encode();
        bytes[8] = 99; // schema u32 little-endian low byte
        let n = bytes.len();
        let c = checksum(&bytes[..n - 8]);
        bytes[n - 8..].copy_from_slice(&c.to_le_bytes());
        assert_eq!(
            PlanArtifact::decode(&bytes),
            Err(ArtifactError::SchemaMismatch { found: 99, want: PLAN_SCHEMA })
        );
    }

    #[test]
    fn structurally_invalid_operands_are_rejected() {
        // non-finite dense value
        let mut m = Mat::eye(3);
        m[(1, 1)] = f64::INFINITY;
        let art = PlanArtifact::new(SystemInput::Dense(m), 0, "b".into(), None);
        assert!(matches!(
            PlanArtifact::decode(&art.encode()),
            Err(ArtifactError::Malformed("non-finite dense operand value"))
        ));
        // CSR column index out of range
        let c = Csr {
            n_rows: 2,
            n_cols: 2,
            row_ptr: vec![0, 1, 2],
            col_idx: vec![0, 7],
            values: vec![1.0, 1.0],
        };
        let art = PlanArtifact::new(SystemInput::Sparse(c), 0, "b".into(), None);
        assert!(matches!(
            PlanArtifact::decode(&art.encode()),
            Err(ArtifactError::Malformed("CSR column index out of range"))
        ));
    }

    #[test]
    fn plan_file_names_are_stable_and_distinct() {
        let a = dense_sys(1, 5).fingerprint();
        let b = dense_sys(2, 5).fingerprint();
        assert_eq!(plan_file_name(&a), plan_file_name(&a));
        assert_ne!(plan_file_name(&a), plan_file_name(&b));
        assert!(plan_file_name(&a).ends_with(".plan"));
    }

    #[test]
    fn batch_planner_groups_by_op_and_bucket() {
        let buckets = [64, 128];
        let items =
            [("lu_solve", 60), ("residual", 100), ("lu_solve", 64), ("lu_solve", 65)];
        let groups = plan_batches(&items, &buckets).unwrap();
        assert_eq!(groups.len(), 3);
        assert_eq!(groups[0], BatchGroup { op: "lu_solve".into(), bucket: 64, items: vec![0, 2] });
        assert_eq!(groups[1], BatchGroup { op: "residual".into(), bucket: 128, items: vec![1] });
        assert_eq!(groups[2], BatchGroup { op: "lu_solve".into(), bucket: 128, items: vec![3] });
    }

    #[test]
    fn batch_planner_rejects_oversize_items() {
        let err = plan_batches(&[("gmres", 200)], &[64, 128]).unwrap_err();
        assert!(err.to_string().contains("no manifest bucket fits"), "{err}");
        assert_eq!(bucket_for(&[64, 128], 128), Some(128));
        assert_eq!(bucket_for(&[64, 128], 129), None);
        assert_eq!(bucket_for(&[128, 64], 10), Some(64), "buckets need not be sorted");
    }
}
