//! The real PJRT client (`pjrt` feature): executable cache, literal
//! marshalling, and the artifact-backed [`SolverBackend`].
//!
//! Batch-native dispatch (ISSUE 10): [`PjrtBackend::lu_solve_batch`]
//! and [`PjrtBackend::residual_batch`] group work by manifest size
//! bucket ([`plan_batches`]), pad to the bucket, and issue one packed
//! executable invocation per (op, bucket) group when the manifest's
//! versioned ops table declares the `{op}_many` artifacts — amortizing
//! the per-call XLA boundary cost that dominates small solves. Older
//! manifests fall back to per-item dispatch against the cached
//! single-item executables, bit-for-bit unchanged.
//!
//! Building this module requires the `xla` crate, which must be added to
//! `[dependencies]` on a networked host — it cannot be vendored offline.
//!
//! Thread-safety: the stateless-session trait requires `Send + Sync`.
//! The executable cache and the PJRT client are guarded by one mutex, so
//! concurrent solves through this backend serialize at the XLA boundary
//! (the native backend is the parallel sweep path; this one exists for
//! cross-validation and artifact serving, where per-call latency is
//! dominated by the executable anyway).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use super::{pad_vec, plan_batches};
use crate::chop::Prec;
use crate::linalg::Mat;
use crate::runtime::Manifest;
use crate::solver::workspace::InnerWs;
use crate::solver::{GmresOutcome, LuHandle, ProblemSession, SolverBackend};

/// Compiled-executable cache over the artifact set.
pub struct PjrtRuntime {
    pub manifest: Manifest,
    dir: String,
    /// PJRT client + compiled executables + per-artifact execution counts
    /// (perf telemetry), all behind one lock: every XLA interaction is
    /// serialized, which is what lets the backend be `Sync`.
    inner: Mutex<RuntimeInner>,
}

struct RuntimeInner {
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    exec_counts: HashMap<String, u64>,
}

// SAFETY: two distinct claims.
// * Sync: all access to the XLA client and executables goes through
//   `inner`'s mutex (no method hands out references to them), so the
//   runtime is never *used* from two threads at once even though the
//   xla crate's types don't advertise Send/Sync themselves.
// * Send: moving (and eventually dropping) the runtime on another
//   thread additionally requires that the PJRT handles are not
//   thread-affine. The PJRT C API specifies its client/executable
//   objects as thread-safe with no thread-affinity requirements, and
//   the CPU plugin allocates with plain host allocators, so destruction
//   from a foreign thread is within contract. If a future plugin
//   violates this, drop the `Send` impl and pin the backend to its
//   creating thread instead.
unsafe impl Send for PjrtRuntime {}
unsafe impl Sync for PjrtRuntime {}

impl PjrtRuntime {
    /// Open the artifact directory (expects `manifest.json` inside).
    pub fn open(dir: &str) -> Result<PjrtRuntime> {
        let manifest = Manifest::load(&format!("{dir}/manifest.json"))
            .with_context(|| format!("loading manifest from {dir} (run `make artifacts`)"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        Ok(PjrtRuntime {
            manifest,
            dir: dir.to_string(),
            inner: Mutex::new(RuntimeInner {
                client,
                exes: HashMap::new(),
                exec_counts: HashMap::new(),
            }),
        })
    }

    /// Smallest bucket >= n (error if none).
    pub fn bucket_for(&self, n: usize) -> Result<usize> {
        self.manifest
            .buckets
            .iter()
            .copied()
            .filter(|&b| b >= n)
            .min()
            .ok_or_else(|| {
                anyhow!(
                    "no artifact bucket fits n={n} (buckets: {:?}); regenerate with larger --buckets",
                    self.manifest.buckets
                )
            })
    }

    /// Execute an artifact with the given inputs (compiling + caching the
    /// executable on first use); returns the output tuple elements as
    /// Literals.
    pub fn run(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let mut inner = self.inner.lock().unwrap();
        *inner.exec_counts.entry(name.to_string()).or_insert(0) += 1;
        if !inner.exes.contains_key(name) {
            let meta = self
                .manifest
                .by_name(name)
                .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))?;
            let path = format!("{}/{}", self.dir, meta.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing {path}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = inner
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e}"))?;
            inner.exes.insert(name.to_string(), exe);
        }
        let exe = &inner.exes[name];
        let out = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {name}: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e}"))?;
        out.to_tuple().map_err(|e| anyhow!("untupling {name}: {e}"))
    }

    pub fn artifacts_compiled(&self) -> usize {
        self.inner.lock().unwrap().exes.len()
    }

    /// Executions of one artifact so far (perf telemetry).
    pub fn exec_count(&self, name: &str) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .exec_counts
            .get(name)
            .copied()
            .unwrap_or(0)
    }
}

// ---------------------------------------------------------------------------
// literal marshalling helpers
// ---------------------------------------------------------------------------

pub fn mat_literal(a: &Mat) -> Result<xla::Literal> {
    xla::Literal::vec1(&a.data)
        .reshape(&[a.n_rows as i64, a.n_cols as i64])
        .map_err(|e| anyhow!("reshape literal: {e}"))
}

pub fn vec_literal(v: &[f64]) -> xla::Literal {
    xla::Literal::vec1(v)
}

pub fn ivec_literal(v: &[i32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

pub fn literal_to_f64s(l: &xla::Literal) -> Result<Vec<f64>> {
    l.to_vec::<f64>().map_err(|e| anyhow!("literal->f64s: {e}"))
}

pub fn literal_to_i32s(l: &xla::Literal) -> Result<Vec<i32>> {
    l.to_vec::<i32>().map_err(|e| anyhow!("literal->i32s: {e}"))
}

pub fn literal_scalar_f64(l: &xla::Literal) -> Result<f64> {
    l.get_first_element::<f64>()
        .map_err(|e| anyhow!("literal->f64: {e}"))
}

pub fn literal_scalar_i32(l: &xla::Literal) -> Result<i32> {
    l.get_first_element::<i32>()
        .map_err(|e| anyhow!("literal->i32: {e}"))
}

// ---------------------------------------------------------------------------
// the backend
// ---------------------------------------------------------------------------

/// [`SolverBackend`] over the AOT artifacts. All reduced-precision
/// arithmetic happens *inside* the artifacts (the Pallas chop kernel);
/// only f64 buffers cross the PJRT boundary. The padded copy of A is
/// cached in the caller's [`ProblemSession`]; the backend holds only the
/// (lock-guarded) executable cache.
pub struct PjrtBackend {
    pub rt: PjrtRuntime,
}

impl PjrtBackend {
    pub fn open(dir: &str) -> Result<PjrtBackend> {
        Ok(PjrtBackend { rt: PjrtRuntime::open(dir)? })
    }

    fn padded_a<'s>(&self, s: &'s ProblemSession<'_>) -> Result<(usize, &'s Mat)> {
        let nb = self.rt.bucket_for(s.n())?;
        Ok((nb, s.padded(nb)))
    }

    fn artifact(&self, op: &str, p: Prec, nb: usize) -> String {
        format!("{op}_{}_{nb}", p.name())
    }

    /// Width of one packed `{op}_many` invocation: the leading dimension
    /// of the artifact's packed input, read from the manifest signature
    /// (the versioned ops table lets newer artifact sets declare batch
    /// ops without Rust-side constants). `None` when the manifest does
    /// not ship the batch artifact — callers fall back to per-item
    /// dispatch against the cached single-item executable.
    fn many_width(&self, name: &str, input_idx: usize) -> Option<usize> {
        self.rt
            .manifest
            .by_name(name)
            .and_then(|m| m.inputs.get(input_idx))
            .and_then(|io| io.shape.first().copied())
            .filter(|&w| w > 0)
    }

    /// Many-RHS dispatch against one factorization: `LU X = B` for every
    /// rhs in `bs`, packed `many_width` rows per device call against the
    /// `lu_solve_many` artifact when the manifest declares it. The tail
    /// chunk is zero-padded to the packed width (the identity block of
    /// the padded factor maps zero rhs to zero, so unpacking is a plain
    /// truncate). Output order matches input order either way.
    pub fn lu_solve_batch(&self, f: &LuHandle, bs: &[Vec<f64>], p: Prec) -> Result<Vec<Vec<f64>>> {
        let nb = f.lu.n_rows;
        let many = self.artifact("lu_solve_many", p, nb);
        let Some(width) = self.many_width(&many, 2) else {
            // pre-batch manifest: still one compile + k executions, the
            // executable cache amortizes everything but the call
            return bs.iter().map(|b| self.lu_solve(f, b, p)).collect();
        };
        let mut out = Vec::with_capacity(bs.len());
        for chunk in bs.chunks(width) {
            let mut packed = vec![0.0; width * nb];
            for (i, b) in chunk.iter().enumerate() {
                let take = b.len().min(nb);
                packed[i * nb..i * nb + take].copy_from_slice(&b[..take]);
            }
            let b_lit = xla::Literal::vec1(&packed)
                .reshape(&[width as i64, nb as i64])
                .map_err(|e| anyhow!("reshape packed rhs: {e}"))?;
            let outs = self.rt.run(&many, &[mat_literal(&f.lu)?, ivec_literal(&f.piv), b_lit])?;
            let xs = literal_to_f64s(&outs[0])?;
            for (i, b) in chunk.iter().enumerate() {
                let mut x = xs[i * nb..(i + 1) * nb].to_vec();
                x.truncate(b.len());
                out.push(x);
            }
        }
        Ok(out)
    }

    /// Many-system residual sweep: group items by manifest size bucket
    /// ([`plan_batches`]), pad every operand to its group's bucket, and
    /// issue one packed `residual_many` invocation per (op, bucket)
    /// group when the artifact exists — per-item dispatch otherwise.
    /// Output order matches input order.
    pub fn residual_batch(
        &self,
        items: &[(&ProblemSession<'_>, &[f64], &[f64])],
        p: Prec,
    ) -> Result<Vec<Vec<f64>>> {
        let sized: Vec<(&str, usize)> =
            items.iter().map(|(s, _, _)| ("residual", s.n())).collect();
        let groups = plan_batches(&sized, &self.rt.manifest.buckets)?;
        let mut out: Vec<Vec<f64>> = vec![Vec::new(); items.len()];
        for g in groups {
            let nb = g.bucket;
            let many = self.artifact("residual_many", p, nb);
            let Some(width) = self.many_width(&many, 0) else {
                for &idx in &g.items {
                    let (s, x, b) = items[idx];
                    out[idx] = self.residual(s, x, b, p)?;
                }
                continue;
            };
            for chunk in g.items.chunks(width) {
                let mut a_packed = vec![0.0; width * nb * nb];
                let mut x_packed = vec![0.0; width * nb];
                let mut b_packed = vec![0.0; width * nb];
                for (i, &idx) in chunk.iter().enumerate() {
                    let (s, x, b) = items[idx];
                    let ap = s.padded(nb);
                    a_packed[i * nb * nb..(i + 1) * nb * nb].copy_from_slice(&ap.data);
                    x_packed[i * nb..i * nb + x.len()].copy_from_slice(x);
                    b_packed[i * nb..i * nb + b.len()].copy_from_slice(b);
                }
                let a_lit = xla::Literal::vec1(&a_packed)
                    .reshape(&[width as i64, nb as i64, nb as i64])
                    .map_err(|e| anyhow!("reshape packed a: {e}"))?;
                let x_lit = xla::Literal::vec1(&x_packed)
                    .reshape(&[width as i64, nb as i64])
                    .map_err(|e| anyhow!("reshape packed x: {e}"))?;
                let b_lit = xla::Literal::vec1(&b_packed)
                    .reshape(&[width as i64, nb as i64])
                    .map_err(|e| anyhow!("reshape packed b: {e}"))?;
                let outs = self.rt.run(&many, &[a_lit, x_lit, b_lit])?;
                let rs = literal_to_f64s(&outs[0])?;
                for (i, &idx) in chunk.iter().enumerate() {
                    let (_, x, _) = items[idx];
                    let mut r = rs[i * nb..(i + 1) * nb].to_vec();
                    r.truncate(x.len());
                    out[idx] = r;
                }
            }
        }
        Ok(out)
    }
}

impl SolverBackend for PjrtBackend {
    fn lu_factor(&self, s: &ProblemSession<'_>, p: Prec) -> Result<LuHandle> {
        let (nb, ap) = self.padded_a(s)?;
        let name = self.artifact("lu_factor", p, nb);
        let outs = self.rt.run(&name, &[mat_literal(ap)?])?;
        let ok = literal_scalar_i32(&outs[2])?;
        if ok == 0 {
            bail!("LU breakdown in artifact {name}");
        }
        let lu_data = literal_to_f64s(&outs[0])?;
        let piv = literal_to_i32s(&outs[1])?;
        Ok(LuHandle {
            lu: Arc::new(Mat { n_rows: nb, n_cols: nb, data: lu_data }),
            piv,
            prec: p,
        })
    }

    fn lu_solve(&self, f: &LuHandle, b: &[f64], p: Prec) -> Result<Vec<f64>> {
        let nb = f.lu.n_rows;
        let name = self.artifact("lu_solve", p, nb);
        let outs = self.rt.run(
            &name,
            &[
                mat_literal(&f.lu)?,
                ivec_literal(&f.piv),
                vec_literal(&pad_vec(b, nb)),
            ],
        )?;
        let mut x = literal_to_f64s(&outs[0])?;
        x.truncate(b.len());
        Ok(x)
    }

    fn residual(&self, s: &ProblemSession<'_>, x: &[f64], b: &[f64], p: Prec) -> Result<Vec<f64>> {
        let (nb, ap) = self.padded_a(s)?;
        let name = self.artifact("residual", p, nb);
        let outs = self.rt.run(
            &name,
            &[
                mat_literal(ap)?,
                vec_literal(&pad_vec(x, nb)),
                vec_literal(&pad_vec(b, nb)),
            ],
        )?;
        let mut r = literal_to_f64s(&outs[0])?;
        r.truncate(x.len());
        Ok(r)
    }

    fn gmres(
        &self,
        s: &ProblemSession<'_>,
        f: &LuHandle,
        r: &[f64],
        tol: f64,
        max_m: usize,
        p: Prec,
    ) -> Result<GmresOutcome> {
        let (nb, ap) = self.padded_a(s)?;
        let name = self.artifact("gmres", p, nb);
        let outs = self.rt.run(
            &name,
            &[
                mat_literal(ap)?,
                mat_literal(&f.lu)?,
                ivec_literal(&f.piv),
                vec_literal(&pad_vec(r, nb)),
                xla::Literal::scalar(tol),
                xla::Literal::scalar(max_m.min(self.rt.manifest.gmres_max_m) as i32),
            ],
        )?;
        let mut z = literal_to_f64s(&outs[0])?;
        z.truncate(r.len());
        Ok(GmresOutcome {
            z,
            iters: literal_scalar_i32(&outs[1])? as usize,
            relres: literal_scalar_f64(&outs[2])?,
            ok: literal_scalar_i32(&outs[3])? != 0,
        })
    }

    /// Workspace seam (PR 5): the device does the arithmetic, so the
    /// win here is buffer reuse on the host side of the marshalling —
    /// the caller's scratch holds the padded copies and receives the
    /// result without an intermediate allocation per refinement step.
    /// Bit-identical to [`SolverBackend::residual`]: same artifact,
    /// same padded operands.
    fn residual_into(
        &self,
        s: &ProblemSession<'_>,
        x: &[f64],
        b: &[f64],
        p: Prec,
        xc: &mut Vec<f64>,
        out: &mut Vec<f64>,
    ) -> Result<()> {
        let (nb, ap) = self.padded_a(s)?;
        xc.clear();
        xc.extend_from_slice(x);
        xc.resize(nb, 0.0);
        out.clear();
        out.extend_from_slice(b);
        out.resize(nb, 0.0);
        let name = self.artifact("residual", p, nb);
        let outs =
            self.rt.run(&name, &[mat_literal(ap)?, vec_literal(xc), vec_literal(out)])?;
        let r = literal_to_f64s(&outs[0])?;
        out.clear();
        out.extend_from_slice(&r[..x.len()]);
        Ok(())
    }

    /// Workspace seam (PR 5): GMRES scratch lives device-side in the
    /// artifact, so `ws` is unused; the correction lands directly in the
    /// caller's buffer. Bit-identical to [`SolverBackend::gmres`].
    fn gmres_ws(
        &self,
        s: &ProblemSession<'_>,
        f: &LuHandle,
        r: &[f64],
        tol: f64,
        max_m: usize,
        p: Prec,
        ws: &mut InnerWs,
        z_out: &mut Vec<f64>,
    ) -> Result<(usize, bool)> {
        let _ = ws;
        let g = self.gmres(s, f, r, tol, max_m, p)?;
        z_out.clear();
        z_out.extend_from_slice(&g.z);
        Ok((g.iters, g.ok))
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}
