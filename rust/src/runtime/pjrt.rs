//! The real PJRT client (`pjrt` feature): executable cache, literal
//! marshalling, and the artifact-backed [`SolverBackend`].
//!
//! Building this module requires the `xla` crate, which must be added to
//! `[dependencies]` on a networked host — it cannot be vendored offline.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use super::{pad_matrix, pad_vec};
use crate::backend_native::fingerprint;
use crate::chop::Prec;
use crate::linalg::Mat;
use crate::runtime::Manifest;
use crate::solver::{GmresOutcome, LuHandle, SolverBackend};

/// Compiled-executable cache over the artifact set.
pub struct PjrtRuntime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: String,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    /// executions per artifact name (perf telemetry)
    pub exec_counts: HashMap<String, u64>,
}

impl PjrtRuntime {
    /// Open the artifact directory (expects `manifest.json` inside).
    pub fn open(dir: &str) -> Result<PjrtRuntime> {
        let manifest = Manifest::load(&format!("{dir}/manifest.json"))
            .with_context(|| format!("loading manifest from {dir} (run `make artifacts`)"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        Ok(PjrtRuntime {
            client,
            manifest,
            dir: dir.to_string(),
            exes: HashMap::new(),
            exec_counts: HashMap::new(),
        })
    }

    /// Smallest bucket >= n (error if none).
    pub fn bucket_for(&self, n: usize) -> Result<usize> {
        self.manifest
            .buckets
            .iter()
            .copied()
            .filter(|&b| b >= n)
            .min()
            .ok_or_else(|| {
                anyhow!(
                    "no artifact bucket fits n={n} (buckets: {:?}); regenerate with larger --buckets",
                    self.manifest.buckets
                )
            })
    }

    /// Get (compiling + caching on first use) the executable for `name`.
    pub fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.exes.contains_key(name) {
            let meta = self
                .manifest
                .by_name(name)
                .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))?;
            let path = format!("{}/{}", self.dir, meta.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing {path}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e}"))?;
            self.exes.insert(name.to_string(), exe);
        }
        Ok(&self.exes[name])
    }

    /// Execute an artifact with the given inputs; returns the output
    /// tuple elements as Literals.
    pub fn run(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        *self.exec_counts.entry(name.to_string()).or_insert(0) += 1;
        let exe = self.executable(name)?;
        let out = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {name}: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e}"))?;
        out.to_tuple().map_err(|e| anyhow!("untupling {name}: {e}"))
    }

    pub fn artifacts_compiled(&self) -> usize {
        self.exes.len()
    }
}

// ---------------------------------------------------------------------------
// literal marshalling helpers
// ---------------------------------------------------------------------------

pub fn mat_literal(a: &Mat) -> Result<xla::Literal> {
    xla::Literal::vec1(&a.data)
        .reshape(&[a.n_rows as i64, a.n_cols as i64])
        .map_err(|e| anyhow!("reshape literal: {e}"))
}

pub fn vec_literal(v: &[f64]) -> xla::Literal {
    xla::Literal::vec1(v)
}

pub fn ivec_literal(v: &[i32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

pub fn literal_to_f64s(l: &xla::Literal) -> Result<Vec<f64>> {
    l.to_vec::<f64>().map_err(|e| anyhow!("literal->f64s: {e}"))
}

pub fn literal_to_i32s(l: &xla::Literal) -> Result<Vec<i32>> {
    l.to_vec::<i32>().map_err(|e| anyhow!("literal->i32s: {e}"))
}

pub fn literal_scalar_f64(l: &xla::Literal) -> Result<f64> {
    l.get_first_element::<f64>()
        .map_err(|e| anyhow!("literal->f64: {e}"))
}

pub fn literal_scalar_i32(l: &xla::Literal) -> Result<i32> {
    l.get_first_element::<i32>()
        .map_err(|e| anyhow!("literal->i32: {e}"))
}

// ---------------------------------------------------------------------------
// the backend
// ---------------------------------------------------------------------------

/// [`SolverBackend`] over the AOT artifacts. All reduced-precision
/// arithmetic happens *inside* the artifacts (the Pallas chop kernel);
/// only f64 buffers cross the PJRT boundary.
pub struct PjrtBackend {
    pub rt: PjrtRuntime,
    /// (fingerprint, bucket) -> padded A, reused (by Arc, no copy) across
    /// the steps and outer iterations of one solve
    a_pad_cache: Option<(u64, usize, Arc<Mat>)>,
}

impl PjrtBackend {
    pub fn open(dir: &str) -> Result<PjrtBackend> {
        Ok(PjrtBackend { rt: PjrtRuntime::open(dir)?, a_pad_cache: None })
    }

    fn padded_a(&mut self, a: &Mat) -> Result<(usize, Arc<Mat>)> {
        let nb = self.rt.bucket_for(a.n_rows)?;
        let fp = fingerprint(a);
        if let Some((cfp, cnb, cached)) = &self.a_pad_cache {
            if *cfp == fp && *cnb == nb {
                return Ok((nb, Arc::clone(cached)));
            }
        }
        let p = Arc::new(pad_matrix(a, nb));
        self.a_pad_cache = Some((fp, nb, Arc::clone(&p)));
        Ok((nb, p))
    }

    fn artifact(&self, op: &str, p: Prec, nb: usize) -> String {
        format!("{op}_{}_{nb}", p.name())
    }
}

impl SolverBackend for PjrtBackend {
    fn lu_factor(&mut self, a: &Mat, p: Prec) -> Result<LuHandle> {
        let (nb, ap) = self.padded_a(a)?;
        let name = self.artifact("lu_factor", p, nb);
        let outs = self.rt.run(&name, &[mat_literal(&ap)?])?;
        let ok = literal_scalar_i32(&outs[2])?;
        if ok == 0 {
            bail!("LU breakdown in artifact {name}");
        }
        let lu_data = literal_to_f64s(&outs[0])?;
        let piv = literal_to_i32s(&outs[1])?;
        Ok(LuHandle {
            lu: Arc::new(Mat { n_rows: nb, n_cols: nb, data: lu_data }),
            piv,
            prec: p,
        })
    }

    fn lu_solve(&mut self, f: &LuHandle, b: &[f64], p: Prec) -> Result<Vec<f64>> {
        let nb = f.lu.n_rows;
        let name = self.artifact("lu_solve", p, nb);
        let outs = self.rt.run(
            &name,
            &[
                mat_literal(&f.lu)?,
                ivec_literal(&f.piv),
                vec_literal(&pad_vec(b, nb)),
            ],
        )?;
        let mut x = literal_to_f64s(&outs[0])?;
        x.truncate(b.len());
        Ok(x)
    }

    fn residual(&mut self, a: &Mat, x: &[f64], b: &[f64], p: Prec) -> Result<Vec<f64>> {
        let (nb, ap) = self.padded_a(a)?;
        let name = self.artifact("residual", p, nb);
        let outs = self.rt.run(
            &name,
            &[
                mat_literal(&ap)?,
                vec_literal(&pad_vec(x, nb)),
                vec_literal(&pad_vec(b, nb)),
            ],
        )?;
        let mut r = literal_to_f64s(&outs[0])?;
        r.truncate(x.len());
        Ok(r)
    }

    fn gmres(
        &mut self,
        a: &Mat,
        f: &LuHandle,
        r: &[f64],
        tol: f64,
        max_m: usize,
        p: Prec,
    ) -> Result<GmresOutcome> {
        let (nb, ap) = self.padded_a(a)?;
        let name = self.artifact("gmres", p, nb);
        let outs = self.rt.run(
            &name,
            &[
                mat_literal(&ap)?,
                mat_literal(&f.lu)?,
                ivec_literal(&f.piv),
                vec_literal(&pad_vec(r, nb)),
                xla::Literal::scalar(tol),
                xla::Literal::scalar(max_m.min(self.rt.manifest.gmres_max_m) as i32),
            ],
        )?;
        let mut z = literal_to_f64s(&outs[0])?;
        z.truncate(r.len());
        Ok(GmresOutcome {
            z,
            iters: literal_scalar_i32(&outs[1])? as usize,
            relres: literal_scalar_f64(&outs[2])?,
            ok: literal_scalar_i32(&outs[3])? != 0,
        })
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn reset(&mut self) {
        self.a_pad_cache = None;
    }
}
