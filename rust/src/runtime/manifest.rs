//! `artifacts/manifest.json` reader: the contract between `aot.py` and
//! the Rust runtime (artifact names, I/O signatures, buckets, formats).

use anyhow::{Context, Result};

use crate::util::json::{parse, Value};

#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f64" | "i32"
}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub op: String,
    pub fmt: String,
    pub n: usize,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// The op table a manifest without an explicit `"ops"` array is checked
/// against — the original four plan ops. Manifests that compile more
/// (or fewer) ops declare their own table; completeness is then judged
/// against what the manifest *claims* to ship instead of this snapshot
/// of history.
pub const DEFAULT_OPS: [&str; 4] = ["lu_factor", "lu_solve", "residual", "gmres"];

#[derive(Clone, Debug)]
pub struct Manifest {
    pub buckets: Vec<usize>,
    pub formats: Vec<String>,
    pub gmres_max_m: usize,
    /// Versioned op table: the ops [`Manifest::is_complete`] demands for
    /// every (fmt, bucket). Read from the manifest's `"ops"` array;
    /// [`DEFAULT_OPS`] when absent (older manifests).
    pub ops: Vec<String>,
    pub artifacts: Vec<ArtifactMeta>,
}

fn io_specs(v: &Value) -> Result<Vec<IoSpec>> {
    v.as_arr()?
        .iter()
        .map(|e| {
            Ok(IoSpec {
                name: e.get("name")?.as_str()?.to_string(),
                shape: e
                    .get("shape")?
                    .as_arr()?
                    .iter()
                    .map(|x| x.as_usize())
                    .collect::<Result<_>>()?,
                dtype: e.get("dtype")?.as_str()?.to_string(),
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(path: &str) -> Result<Manifest> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        Manifest::from_json_text(&text)
    }

    pub fn from_json_text(text: &str) -> Result<Manifest> {
        let v = parse(text)?;
        let buckets = v
            .get("buckets")?
            .as_arr()?
            .iter()
            .map(|x| x.as_usize())
            .collect::<Result<_>>()?;
        let formats = v
            .get("formats")?
            .as_arr()?
            .iter()
            .map(|x| Ok(x.as_str()?.to_string()))
            .collect::<Result<_>>()?;
        let gmres_max_m = v.get("gmres_max_m")?.as_usize()?;
        let ops: Vec<String> = match v.get("ops") {
            Ok(o) if !matches!(o, Value::Null) => o
                .as_arr()?
                .iter()
                .map(|x| Ok(x.as_str()?.to_string()))
                .collect::<Result<_>>()?,
            _ => DEFAULT_OPS.iter().map(|s| s.to_string()).collect(),
        };
        let artifacts = v
            .get("artifacts")?
            .as_arr()?
            .iter()
            .map(|a| {
                Ok(ArtifactMeta {
                    name: a.get("name")?.as_str()?.to_string(),
                    op: a.get("op")?.as_str()?.to_string(),
                    fmt: a.get("fmt")?.as_str()?.to_string(),
                    n: a.get("n")?.as_usize()?,
                    file: a.get("file")?.as_str()?.to_string(),
                    inputs: io_specs(a.get("inputs")?)?,
                    outputs: io_specs(a.get("outputs")?)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { buckets, formats, gmres_max_m, ops, artifacts })
    }

    pub fn by_name(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Completeness check: every (op, fmt, bucket) combination of the
    /// manifest's own op table ([`Manifest::ops`]) present — a manifest
    /// that grows a new op cannot silently pass by matching a hardcoded
    /// historical list.
    pub fn is_complete(&self) -> bool {
        for op in &self.ops {
            for f in &self.formats {
                for &b in &self.buckets {
                    if self.by_name(&format!("{op}_{f}_{b}")).is_none() {
                        return false;
                    }
                }
            }
        }
        !self.ops.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
 "version": 1, "gmres_max_m": 50,
 "buckets": [64, 128], "formats": ["bf16", "fp64"],
 "artifacts": [
  {"name": "lu_factor_bf16_64", "op": "lu_factor", "fmt": "bf16", "n": 64,
   "file": "lu_factor_bf16_64.hlo.txt",
   "inputs": [{"name": "a", "shape": [64, 64], "dtype": "f64"}],
   "outputs": [{"name": "lu", "shape": [64, 64], "dtype": "f64"},
               {"name": "piv", "shape": [64], "dtype": "i32"},
               {"name": "ok", "shape": [], "dtype": "i32"}],
   "sha256": "abc"}
 ]}"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::from_json_text(SAMPLE).unwrap();
        assert_eq!(m.buckets, vec![64, 128]);
        assert_eq!(m.formats, vec!["bf16", "fp64"]);
        assert_eq!(m.gmres_max_m, 50);
        let a = m.by_name("lu_factor_bf16_64").unwrap();
        assert_eq!(a.inputs[0].shape, vec![64, 64]);
        assert_eq!(a.outputs[1].dtype, "i32");
        assert_eq!(a.outputs[2].shape.len(), 0);
    }

    #[test]
    fn incomplete_detected() {
        let m = Manifest::from_json_text(SAMPLE).unwrap();
        assert!(!m.is_complete()); // only 1 of 16 combos present
    }

    #[test]
    fn missing_name_is_none() {
        let m = Manifest::from_json_text(SAMPLE).unwrap();
        assert!(m.by_name("nope").is_none());
    }

    #[test]
    fn ops_table_defaults_to_the_original_four() {
        let m = Manifest::from_json_text(SAMPLE).unwrap();
        assert_eq!(m.ops, DEFAULT_OPS.map(|s| s.to_string()).to_vec());
    }

    #[test]
    fn declared_ops_table_drives_completeness() {
        // one op, one format, one bucket, fully shipped => complete
        let text = r#"{
         "version": 1, "gmres_max_m": 50,
         "buckets": [64], "formats": ["fp64"], "ops": ["lu_factor"],
         "artifacts": [
          {"name": "lu_factor_fp64_64", "op": "lu_factor", "fmt": "fp64", "n": 64,
           "file": "lu_factor_fp64_64.hlo.txt", "inputs": [], "outputs": []}
         ]}"#;
        let m = Manifest::from_json_text(text).unwrap();
        assert_eq!(m.ops, vec!["lu_factor"]);
        assert!(m.is_complete(), "completeness judged against the declared table");
        // the same artifact set against a table that also demands a new
        // op must fail instead of silently passing on the old list
        let grown = text.replace(r#""ops": ["lu_factor"]"#, r#""ops": ["lu_factor", "batch_solve"]"#);
        let m = Manifest::from_json_text(&grown).unwrap();
        assert!(!m.is_complete(), "missing declared op detected");
        // an empty table never vacuously passes
        let empty = text.replace(r#""ops": ["lu_factor"]"#, r#""ops": []"#);
        assert!(!Manifest::from_json_text(&empty).unwrap().is_complete());
    }
}
