//! CSR sparse matrices — substrate for the §5.3 sparse experiments.
//!
//! The paper's sparse systems (n ≤ 500, λ_s = 0.01, A = A₀A₀ᵀ + βI) are
//! factorized densely (as in the paper's own Python simulation), but the
//! CSR form carries the structural features (sparsity, bandwidth,
//! diagonal dominance) and provides a fast matvec used by tests and the
//! feature extractor.

use crate::linalg::Mat;

/// Compressed sparse row matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub n_rows: usize,
    pub n_cols: usize,
    pub row_ptr: Vec<usize>,
    pub col_idx: Vec<usize>,
    pub values: Vec<f64>,
}

impl Csr {
    /// Build from (row, col, value) triplets; duplicate entries sum.
    pub fn from_triplets(
        n_rows: usize,
        n_cols: usize,
        triplets: &[(usize, usize, f64)],
    ) -> Csr {
        let mut per_row: Vec<Vec<(usize, f64)>> = vec![Vec::new(); n_rows];
        for &(i, j, v) in triplets {
            assert!(i < n_rows && j < n_cols, "triplet out of bounds");
            per_row[i].push((j, v));
        }
        let mut row_ptr = Vec::with_capacity(n_rows + 1);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        row_ptr.push(0);
        for row in per_row.iter_mut() {
            row.sort_by_key(|&(j, _)| j);
            let mut k = 0;
            while k < row.len() {
                let j = row[k].0;
                let mut v = 0.0;
                while k < row.len() && row[k].0 == j {
                    v += row[k].1;
                    k += 1;
                }
                if v != 0.0 {
                    col_idx.push(j);
                    values.push(v);
                }
            }
            row_ptr.push(col_idx.len());
        }
        Csr { n_rows, n_cols, row_ptr, col_idx, values }
    }

    pub fn from_dense(a: &Mat) -> Csr {
        let mut triplets = Vec::new();
        for i in 0..a.n_rows {
            for j in 0..a.n_cols {
                if a[(i, j)] != 0.0 {
                    triplets.push((i, j, a[(i, j)]));
                }
            }
        }
        Csr::from_triplets(a.n_rows, a.n_cols, &triplets)
    }

    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.n_rows, self.n_cols);
        for i in 0..self.n_rows {
            for k in self.row_ptr[i]..self.row_ptr[i + 1] {
                m[(i, self.col_idx[k])] = self.values[k];
            }
        }
        m
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Fraction of structurally non-zero entries (paper Table 3's
    /// "Sparsity" column reports this as a percentage).
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.n_rows * self.n_cols) as f64
    }

    /// y = A x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n_cols);
        let mut y = vec![0.0; self.n_rows];
        for i in 0..self.n_rows {
            let mut acc = 0.0;
            for k in self.row_ptr[i]..self.row_ptr[i + 1] {
                acc += self.values[k] * x[self.col_idx[k]];
            }
            y[i] = acc;
        }
        y
    }

    /// ‖A‖∞.
    pub fn norm_inf(&self) -> f64 {
        (0..self.n_rows)
            .map(|i| {
                self.values[self.row_ptr[i]..self.row_ptr[i + 1]]
                    .iter()
                    .map(|v| v.abs())
                    .sum::<f64>()
            })
            .fold(0.0, f64::max)
    }

    /// C = A·Aᵀ, returned dense (the §5.3 generator's A₀A₀ᵀ step; result
    /// is structurally fairly dense, so dense output is the right call).
    pub fn aat_dense(&self) -> Mat {
        let mut c = Mat::zeros(self.n_rows, self.n_rows);
        // (A Aᵀ)_{ij} = <row_i, row_j>; exploit sparsity of row_i.
        for i in 0..self.n_rows {
            let (si, ei) = (self.row_ptr[i], self.row_ptr[i + 1]);
            for j in i..self.n_rows {
                let (sj, ej) = (self.row_ptr[j], self.row_ptr[j + 1]);
                let mut acc = 0.0;
                let (mut p, mut q) = (si, sj);
                while p < ei && q < ej {
                    match self.col_idx[p].cmp(&self.col_idx[q]) {
                        std::cmp::Ordering::Less => p += 1,
                        std::cmp::Ordering::Greater => q += 1,
                        std::cmp::Ordering::Equal => {
                            acc += self.values[p] * self.values[q];
                            p += 1;
                            q += 1;
                        }
                    }
                }
                c[(i, j)] = acc;
                c[(j, i)] = acc;
            }
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn triplets_roundtrip_dense() {
        let a = Mat::from_rows(&[&[1.0, 0.0, 2.0], &[0.0, 0.0, 0.0], &[3.0, 4.0, 0.0]]);
        let s = Csr::from_dense(&a);
        assert_eq!(s.nnz(), 4);
        assert_eq!(s.to_dense(), a);
    }

    #[test]
    fn duplicates_sum_and_zeros_drop() {
        let s = Csr::from_triplets(2, 2, &[(0, 0, 1.0), (0, 0, 2.0), (1, 1, 3.0), (1, 0, 0.0)]);
        assert_eq!(s.nnz(), 2);
        assert_eq!(s.to_dense()[(0, 0)], 3.0);
    }

    #[test]
    fn matvec_matches_dense() {
        use crate::util::proptest::{check, gen};
        check("csr_matvec", 31, 30, |rng| {
            let n = gen::size(rng, 1, 40);
            let m = gen::size(rng, 1, 40);
            let mut a = Mat::zeros(m, n);
            for v in a.data.iter_mut() {
                if rng.uniform() < 0.15 {
                    *v = rng.gauss();
                }
            }
            let x: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
            let s = Csr::from_dense(&a);
            let y1 = s.matvec(&x);
            let y2 = a.matvec(&x);
            for (u, v) in y1.iter().zip(&y2) {
                crate::prop_assert!((u - v).abs() < 1e-12, "{u} vs {v}");
            }
            Ok(())
        });
    }

    #[test]
    fn aat_matches_dense_computation() {
        use crate::util::proptest::{check, gen};
        check("csr_aat", 33, 15, |rng| {
            let n = gen::size(rng, 1, 25);
            let mut a = Mat::zeros(n, n);
            for v in a.data.iter_mut() {
                if rng.uniform() < 0.2 {
                    *v = rng.gauss();
                }
            }
            let s = Csr::from_dense(&a);
            let got = s.aat_dense();
            let want = a.matmul(&a.transpose());
            for i in 0..n {
                for j in 0..n {
                    crate::prop_assert!(
                        (got[(i, j)] - want[(i, j)]).abs() < 1e-11,
                        "({i},{j})"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn norm_inf_matches_dense() {
        let a = Mat::from_rows(&[&[1.0, -2.0], &[-3.0, 4.0]]);
        assert_eq!(Csr::from_dense(&a).norm_inf(), a.norm_inf());
    }

    #[test]
    fn density_fraction() {
        let s = Csr::from_triplets(10, 10, &[(0, 0, 1.0), (5, 5, 1.0)]);
        assert!((s.density() - 0.02).abs() < 1e-15);
    }
}
