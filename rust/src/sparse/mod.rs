//! CSR sparse matrices — substrate for the §5.3 sparse experiments.
//!
//! The paper's sparse systems (n ≤ 500, λ_s = 0.01, A = A₀A₀ᵀ + βI) are
//! factorized densely (as in the paper's own Python simulation), but the
//! CSR form is a first-class solve input since the
//! [`crate::system::SystemInput`] abstraction (DESIGN.md §2c): the IR
//! loop's residual and GMRES matvecs run O(nnz) through [`Csr::matvec`]
//! and the chopped variant [`Csr::chopped_matvec_prechopped`], both
//! bit-identical to the densified path.

use crate::chop::Prec;
use crate::linalg::Mat;

/// Stored-entry count above which the CSR matvecs dispatch rows to the
/// thread pool (the sparse mirror of `linalg::PAR_MIN_ELEMS`); below it
/// the per-call spawn cost exceeds the arithmetic.
const PAR_MIN_NNZ: usize = 1 << 18;

/// Compressed sparse row matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub n_rows: usize,
    pub n_cols: usize,
    pub row_ptr: Vec<usize>,
    pub col_idx: Vec<usize>,
    pub values: Vec<f64>,
}

impl Csr {
    /// Build from (row, col, value) triplets; duplicate entries sum.
    pub fn from_triplets(
        n_rows: usize,
        n_cols: usize,
        triplets: &[(usize, usize, f64)],
    ) -> Csr {
        let mut per_row: Vec<Vec<(usize, f64)>> = vec![Vec::new(); n_rows];
        for &(i, j, v) in triplets {
            assert!(i < n_rows && j < n_cols, "triplet out of bounds");
            per_row[i].push((j, v));
        }
        let mut row_ptr = Vec::with_capacity(n_rows + 1);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        row_ptr.push(0);
        for row in per_row.iter_mut() {
            row.sort_by_key(|&(j, _)| j);
            let mut k = 0;
            while k < row.len() {
                let j = row[k].0;
                let mut v = 0.0;
                while k < row.len() && row[k].0 == j {
                    v += row[k].1;
                    k += 1;
                }
                if v != 0.0 {
                    col_idx.push(j);
                    values.push(v);
                }
            }
            row_ptr.push(col_idx.len());
        }
        Csr { n_rows, n_cols, row_ptr, col_idx, values }
    }

    pub fn from_dense(a: &Mat) -> Csr {
        let mut triplets = Vec::new();
        for i in 0..a.n_rows {
            for j in 0..a.n_cols {
                if a[(i, j)] != 0.0 {
                    triplets.push((i, j, a[(i, j)]));
                }
            }
        }
        Csr::from_triplets(a.n_rows, a.n_cols, &triplets)
    }

    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.n_rows, self.n_cols);
        for i in 0..self.n_rows {
            for k in self.row_ptr[i]..self.row_ptr[i + 1] {
                m[(i, self.col_idx[k])] = self.values[k];
            }
        }
        m
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Fraction of structurally non-zero entries (paper Table 3's
    /// "Sparsity" column reports this as a percentage).
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.n_rows * self.n_cols) as f64
    }

    /// One row dot, f64 accumulation over the stored entries.
    #[inline]
    fn row_dot(&self, i: usize, x: &[f64]) -> f64 {
        let mut acc = 0.0;
        for k in self.row_ptr[i]..self.row_ptr[i + 1] {
            acc += self.values[k] * x[self.col_idx[k]];
        }
        acc
    }

    /// y = A x. Row-parallel above `PAR_MIN_NNZ` stored entries —
    /// each output element is one independent f64-accumulated row dot,
    /// so the result is bit-identical for any thread count (the same
    /// contract as the dense `Mat::matvec`).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut out = Vec::new();
        self.matvec_into(x, &mut out);
        out
    }

    /// In-place form of [`Csr::matvec`]: writes into `out` (cleared +
    /// refilled — allocation-free once `out` has capacity `n_rows`).
    /// Each element is the same independent f64 row dot on both
    /// branches, so bit-identical to the allocating form for any thread
    /// count.
    pub fn matvec_into(&self, x: &[f64], out: &mut Vec<f64>) {
        assert_eq!(x.len(), self.n_cols);
        out.clear();
        if self.nnz() >= PAR_MIN_NNZ {
            out.resize(self.n_rows, 0.0);
            crate::util::pool::parallel_for_rows(out.as_mut_slice(), 1, |i, slot| {
                slot[0] = self.row_dot(i, x);
            });
            return;
        }
        out.extend((0..self.n_rows).map(|i| self.row_dot(i, x)));
    }

    /// The main diagonal (structurally missing entries are 0.0) — the
    /// Jacobi preconditioner's input, O(nnz) via per-row binary search
    /// (square matrices only; column indices are sorted per row by
    /// construction).
    pub fn diag(&self) -> Vec<f64> {
        assert_eq!(self.n_rows, self.n_cols);
        (0..self.n_rows)
            .map(|i| {
                let (s, e) = (self.row_ptr[i], self.row_ptr[i + 1]);
                match self.col_idx[s..e].binary_search(&i) {
                    Ok(k) => self.values[s + k],
                    Err(_) => 0.0,
                }
            })
            .collect()
    }

    /// ‖A‖∞.
    pub fn norm_inf(&self) -> f64 {
        (0..self.n_rows)
            .map(|i| {
                self.values[self.row_ptr[i]..self.row_ptr[i + 1]]
                    .iter()
                    .map(|v| v.abs())
                    .sum::<f64>()
            })
            .fold(0.0, f64::max)
    }

    /// Same structure, values storage-rounded to `p`. Entries that round
    /// to zero stay *stored* (explicit zeros), keeping the value stream
    /// aligned with the chopped dense form — part of the bit-identity
    /// contract of [`Csr::chopped_matvec_prechopped`].
    pub fn chopped(&self, p: Prec) -> Csr {
        let mut c = self.clone();
        crate::chop::chop_slice(&mut c.values, p);
        c
    }

    /// y = chop(A·x) with `self.values` and `x` already rounded to `p`:
    /// f64 accumulation over the stored entries, one rounding per output
    /// element. Bit-identical to `chopped_matvec_prechopped` on the
    /// chopped dense form for finite `x` (see `chop::kernels`);
    /// row-parallel above `PAR_MIN_NNZ`, bit-identical for any thread
    /// count.
    ///
    /// A non-finite `x` entry (a chopped operand that overflowed to
    /// ±inf) poisons *every* row of the dense reference — its structural
    /// zeros multiply `0.0·inf = NaN` and its stored entries go ±inf —
    /// so the solver deterministically fails there. Skipping the zeros
    /// would let the sparse path sail past that failure; instead the
    /// whole result is poisoned to NaN, which drives GMRES to the exact
    /// same (constant) failure outcome the dense path reaches.
    pub fn chopped_matvec_prechopped(&self, x: &[f64], p: Prec) -> Vec<f64> {
        let mut out = Vec::new();
        self.chopped_matvec_prechopped_into(x, p, &mut out);
        out
    }

    /// In-place form of [`Csr::chopped_matvec_prechopped`]: writes into
    /// `out` (cleared + refilled — allocation-free once `out` has
    /// capacity `n_rows`). Same per-element computation on every branch
    /// incl. the non-finite poisoning, so bit-identical to the
    /// allocating form.
    pub fn chopped_matvec_prechopped_into(&self, x: &[f64], p: Prec, out: &mut Vec<f64>) {
        assert_eq!(x.len(), self.n_cols);
        if x.iter().any(|v| !v.is_finite()) {
            out.clear();
            out.resize(self.n_rows, f64::NAN);
            return;
        }
        if self.nnz() >= PAR_MIN_NNZ {
            out.clear();
            out.resize(self.n_rows, 0.0);
            crate::util::pool::parallel_for_rows(out.as_mut_slice(), 1, |i, slot| {
                slot[0] = crate::chop::chop_p(self.row_dot(i, x), p);
            });
            return;
        }
        crate::chop::chop_csr_matvec_into(
            &self.row_ptr,
            &self.col_idx,
            &self.values,
            x,
            p.format(),
            out,
        );
    }

    /// C = A·Aᵀ + βI computed **directly in CSR** — the §5.3 generator's
    /// product without the old double construction (dense product, then
    /// an O(n²) `from_dense` rescan). Row i is built left-to-right with
    /// the same ascending merge-join dot as [`Csr::aat_dense`], so every
    /// stored value is bit-identical to the dense path's entry (the
    /// mirrored (j,i) dot multiplies the same pairs in the same order
    /// with the factors swapped — f64 multiplication commutes bitwise);
    /// entries whose dot is exactly 0.0 are dropped exactly where
    /// `Csr::from_dense` would drop them.
    pub fn aat_plus_diag(&self, beta: f64) -> Csr {
        assert_eq!(self.n_rows, self.n_cols);
        let n = self.n_rows;
        let mut row_ptr = Vec::with_capacity(n + 1);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        row_ptr.push(0);
        for i in 0..n {
            let (si, ei) = (self.row_ptr[i], self.row_ptr[i + 1]);
            for j in 0..n {
                let (sj, ej) = (self.row_ptr[j], self.row_ptr[j + 1]);
                let mut acc = 0.0;
                let (mut p, mut q) = (si, sj);
                while p < ei && q < ej {
                    match self.col_idx[p].cmp(&self.col_idx[q]) {
                        std::cmp::Ordering::Less => p += 1,
                        std::cmp::Ordering::Greater => q += 1,
                        std::cmp::Ordering::Equal => {
                            acc += self.values[p] * self.values[q];
                            p += 1;
                            q += 1;
                        }
                    }
                }
                if i == j {
                    acc += beta;
                }
                if acc != 0.0 {
                    col_idx.push(j);
                    values.push(acc);
                }
            }
            row_ptr.push(col_idx.len());
        }
        Csr { n_rows: n, n_cols: n, row_ptr, col_idx, values }
    }

    /// C = A·Aᵀ, returned dense (the §5.3 generator's A₀A₀ᵀ step; result
    /// is structurally fairly dense, so dense output is the right call).
    pub fn aat_dense(&self) -> Mat {
        let mut c = Mat::zeros(self.n_rows, self.n_rows);
        // (A Aᵀ)_{ij} = <row_i, row_j>; exploit sparsity of row_i.
        for i in 0..self.n_rows {
            let (si, ei) = (self.row_ptr[i], self.row_ptr[i + 1]);
            for j in i..self.n_rows {
                let (sj, ej) = (self.row_ptr[j], self.row_ptr[j + 1]);
                let mut acc = 0.0;
                let (mut p, mut q) = (si, sj);
                while p < ei && q < ej {
                    match self.col_idx[p].cmp(&self.col_idx[q]) {
                        std::cmp::Ordering::Less => p += 1,
                        std::cmp::Ordering::Greater => q += 1,
                        std::cmp::Ordering::Equal => {
                            acc += self.values[p] * self.values[q];
                            p += 1;
                            q += 1;
                        }
                    }
                }
                c[(i, j)] = acc;
                c[(j, i)] = acc;
            }
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn triplets_roundtrip_dense() {
        let a = Mat::from_rows(&[&[1.0, 0.0, 2.0], &[0.0, 0.0, 0.0], &[3.0, 4.0, 0.0]]);
        let s = Csr::from_dense(&a);
        assert_eq!(s.nnz(), 4);
        assert_eq!(s.to_dense(), a);
    }

    #[test]
    fn duplicates_sum_and_zeros_drop() {
        let s = Csr::from_triplets(2, 2, &[(0, 0, 1.0), (0, 0, 2.0), (1, 1, 3.0), (1, 0, 0.0)]);
        assert_eq!(s.nnz(), 2);
        assert_eq!(s.to_dense()[(0, 0)], 3.0);
    }

    #[test]
    fn matvec_matches_dense() {
        use crate::util::proptest::{check, gen};
        check("csr_matvec", 31, 30, |rng| {
            let n = gen::size(rng, 1, 40);
            let m = gen::size(rng, 1, 40);
            let mut a = Mat::zeros(m, n);
            for v in a.data.iter_mut() {
                if rng.uniform() < 0.15 {
                    *v = rng.gauss();
                }
            }
            let x: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
            let s = Csr::from_dense(&a);
            let y1 = s.matvec(&x);
            let y2 = a.matvec(&x);
            for (u, v) in y1.iter().zip(&y2) {
                crate::prop_assert!((u - v).abs() < 1e-12, "{u} vs {v}");
            }
            Ok(())
        });
    }

    #[test]
    fn aat_matches_dense_computation() {
        use crate::util::proptest::{check, gen};
        check("csr_aat", 33, 15, |rng| {
            let n = gen::size(rng, 1, 25);
            let mut a = Mat::zeros(n, n);
            for v in a.data.iter_mut() {
                if rng.uniform() < 0.2 {
                    *v = rng.gauss();
                }
            }
            let s = Csr::from_dense(&a);
            let got = s.aat_dense();
            let want = a.matmul(&a.transpose());
            for i in 0..n {
                for j in 0..n {
                    crate::prop_assert!(
                        (got[(i, j)] - want[(i, j)]).abs() < 1e-11,
                        "({i},{j})"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn chopped_csr_matvec_bitexact_vs_chop_then_dense() {
        // The bit-identity contract behind the sparse-native IR loop:
        // chopped-CSR matvec == chop-then-dense matvec, every bit, for
        // every Prec, across random sparsity patterns and magnitudes
        // (including entries that underflow to explicit zeros when
        // chopped).
        use crate::util::proptest::{check, gen};
        check("csr_chop_matvec_bitexact", 0x5CA2, 120, |rng| {
            let n = gen::size(rng, 1, 36);
            let m = gen::size(rng, 1, 36);
            let fill = rng.uniform_in(0.02, 0.6);
            let mut a = Mat::zeros(m, n);
            for v in a.data.iter_mut() {
                if rng.uniform() < fill {
                    // wide magnitude band so some entries chop to 0/inf
                    *v = rng.gauss() * rng.uniform_in(-320.0, 40.0).exp2();
                }
            }
            let x: Vec<f64> = (0..n)
                .map(|_| rng.gauss() * rng.uniform_in(-30.0, 30.0).exp2())
                .collect();
            let s = Csr::from_dense(&a);
            for p in Prec::ALL {
                let ac = a.chopped(p);
                let mut xc = x.clone();
                crate::chop::chop_slice(&mut xc, p);
                let want = crate::linalg::chopped_matvec_prechopped(&ac, &xc, p);
                let got = s.chopped(p).chopped_matvec_prechopped(&xc, p);
                crate::prop_assert!(got.len() == want.len(), "len at {p}");
                for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                    crate::prop_assert!(
                        g.to_bits() == w.to_bits() || (g.is_nan() && w.is_nan()),
                        "{p} row {i}: sparse {g:e} vs dense {w:e}"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn non_finite_chopped_operand_poisons_both_paths() {
        // An operand entry that overflowed to ±inf under chopping: the
        // dense reference goes non-finite in every row (structural zeros
        // contribute 0·inf = NaN, stored entries go ±inf), so the solver
        // deterministically fails. The sparse path must not sail past
        // that by skipping the zeros — it poisons the whole result.
        let a = Mat::from_rows(&[&[1.0, 0.0], &[0.0, 2.0]]);
        let s = Csr::from_dense(&a);
        let xc = vec![1.0, f64::INFINITY];
        for p in [Prec::Bf16, Prec::Fp64] {
            let sparse = s.chopped(p).chopped_matvec_prechopped(&xc, p);
            assert!(sparse.iter().all(|v| v.is_nan()), "{p}");
            let dense = crate::linalg::chopped_matvec_prechopped(&a.chopped(p), &xc, p);
            assert!(dense.iter().all(|v| !v.is_finite()), "{p}");
        }
    }

    #[test]
    fn chopped_keeps_structure_and_rounds_values() {
        let s = Csr::from_triplets(2, 2, &[(0, 0, 1.0 + 2f64.powi(-9)), (1, 1, 1e-320)]);
        let c = s.chopped(Prec::Bf16);
        // structure untouched, even though 1e-320 rounds to an explicit 0
        assert_eq!(c.row_ptr, s.row_ptr);
        assert_eq!(c.col_idx, s.col_idx);
        assert_eq!(c.values, vec![1.0, 0.0]);
        // fp64 is the identity
        assert_eq!(s.chopped(Prec::Fp64), s);
    }

    #[test]
    fn aat_plus_diag_matches_dense_path_bitwise() {
        // Satellite: the direct-CSR A₀A₀ᵀ + βI must reproduce the old
        // double-construction path (dense product + rescan) bit for bit,
        // in both its CSR and its derived dense form.
        use crate::util::proptest::{check, gen};
        check("csr_aat_plus_diag", 0xAA7, 30, |rng| {
            let n = gen::size(rng, 1, 28);
            let beta = 10f64.powf(rng.uniform_in(-3.0, 0.0));
            let mut a0 = Mat::zeros(n, n);
            for v in a0.data.iter_mut() {
                if rng.uniform() < 0.15 {
                    *v = rng.gauss();
                }
            }
            let s = Csr::from_dense(&a0);
            let direct = s.aat_plus_diag(beta);
            // the old path
            let mut dense = s.aat_dense();
            for i in 0..n {
                dense[(i, i)] += beta;
            }
            let via_dense = Csr::from_dense(&dense);
            crate::prop_assert!(direct.row_ptr == via_dense.row_ptr, "row_ptr differs");
            crate::prop_assert!(direct.col_idx == via_dense.col_idx, "col_idx differs");
            for (k, (u, v)) in direct.values.iter().zip(&via_dense.values).enumerate() {
                crate::prop_assert!(u.to_bits() == v.to_bits(), "value {k}: {u:e} vs {v:e}");
            }
            let back = direct.to_dense();
            for (k, (u, v)) in back.data.iter().zip(&dense.data).enumerate() {
                crate::prop_assert!(u.to_bits() == v.to_bits(), "dense {k}: {u:e} vs {v:e}");
            }
            Ok(())
        });
    }

    #[test]
    fn norm_inf_matches_dense() {
        let a = Mat::from_rows(&[&[1.0, -2.0], &[-3.0, 4.0]]);
        assert_eq!(Csr::from_dense(&a).norm_inf(), a.norm_inf());
    }

    #[test]
    fn diag_matches_dense_including_structural_zeros() {
        let a = Mat::from_rows(&[
            &[2.5, 0.0, 1.0],
            &[0.0, 0.0, -3.0], // structurally missing diagonal
            &[4.0, 0.0, -0.5],
        ]);
        let s = Csr::from_dense(&a);
        assert_eq!(s.diag(), a.diag());
        assert_eq!(s.diag(), vec![2.5, 0.0, -0.5]);
    }

    #[test]
    fn density_fraction() {
        let s = Csr::from_triplets(10, 10, &[(0, 0, 1.0), (5, 5, 1.0)]);
        assert!((s.density() - 0.02).abs() < 1e-15);
    }
}
