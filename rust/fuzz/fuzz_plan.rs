//! Deterministic structured fuzzer for the solve-plan artifact codec
//! (`runtime::artifact::PlanArtifact::decode`) and the versioned
//! manifest parser (`runtime::manifest::Manifest::from_json_text`) —
//! PR 10 satellite. Zero dependencies: seeded by
//! [`precision_autotune::util::rng::Rng`], it mutates valid encoded
//! artifacts (truncation, bit flips, splices, duplicated and zeroed
//! ranges) and valid manifest JSON, and asserts both parsers **error,
//! never panic** — a corrupt plan must be rejected loudly, not
//! trusted. Every run with the same `--seed` replays the identical
//! input sequence, so a crash report is a one-line repro.
//!
//! Usage: `cargo run --release --bin fuzz-plan -- [--iters 10000] [--seed 1]`
//!
//! Exit status: 0 when every iteration returned (Ok or Err); 1 with
//! the offending seed/iteration printed when a parser panicked.

use std::panic;

use precision_autotune::chop::Prec;
use precision_autotune::gen::sparse_spd;
use precision_autotune::linalg::Mat;
use precision_autotune::runtime::{LuPayload, Manifest, PlanArtifact};
use precision_autotune::system::SystemInput;
use precision_autotune::util::cli::Args;
use precision_autotune::util::rng::Rng;

/// Valid encoded artifacts covering the payload shapes the codec
/// round-trips: dense with a full feature pass (kappa + f64 LU), dense
/// with no features, and a sparse CSR operand.
fn binary_corpus() -> Vec<Vec<u8>> {
    let mut rng = Rng::new(42);
    let n = 6;
    let mut a = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            a[(i, j)] = rng.gauss() + if i == j { n as f64 } else { 0.0 };
        }
    }
    let mut lu = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            lu[(i, j)] = rng.gauss();
        }
    }
    let piv: Vec<i32> = (0..n as i32).collect();
    let csr = sparse_spd(12, 0.3, 1.0, &mut rng);
    let with_features = PlanArtifact::new(
        SystemInput::Dense(a.clone()),
        0x1234_5678_9abc_def0,
        "fuzz-builder v0".to_string(),
        Some((1.5e3, Some(LuPayload { lu, piv, prec: Prec::Fp64 }))),
    );
    let bare = PlanArtifact::new(SystemInput::Dense(a), 0, "fuzz-builder v0".to_string(), None);
    let sparse = PlanArtifact::new(
        SystemInput::Sparse(csr),
        7,
        "fuzz-builder v0".to_string(),
        Some((2.0, None)),
    );
    vec![with_features.encode(), bare.encode(), sparse.encode()]
}

/// Valid manifest JSON, including a declared ops table (the field the
/// completeness check derives from).
fn manifest_corpus() -> Vec<String> {
    vec![
        r#"{
 "version": 1, "gmres_max_m": 50,
 "buckets": [64, 128], "formats": ["bf16", "fp64"],
 "artifacts": [
  {"name": "lu_factor_bf16_64", "op": "lu_factor", "fmt": "bf16", "n": 64,
   "file": "lu_factor_bf16_64.hlo.txt",
   "inputs": [{"name": "a", "shape": [64, 64], "dtype": "f64"}],
   "outputs": [{"name": "lu", "shape": [64, 64], "dtype": "f64"},
               {"name": "piv", "shape": [64], "dtype": "i32"},
               {"name": "ok", "shape": [], "dtype": "i32"}],
   "sha256": "abc"}
 ]}"#
            .to_string(),
        r#"{
 "version": 1, "gmres_max_m": 30,
 "buckets": [16], "formats": ["fp64"],
 "ops": ["lu_factor", "lu_solve", "lu_solve_many"],
 "artifacts": [
  {"name": "lu_solve_many_fp64_16", "op": "lu_solve_many", "fmt": "fp64", "n": 16,
   "file": "lu_solve_many_fp64_16.hlo.txt",
   "inputs": [{"name": "bs", "shape": [8, 16], "dtype": "f64"}],
   "outputs": [{"name": "xs", "shape": [8, 16], "dtype": "f64"}]}
 ]}"#
            .to_string(),
    ]
}

/// Tokens that probe the manifest parser's hardened paths: type
/// confusion, absent keys, oversized counts, nested junk.
const DICT: &[&str] = &[
    "\"ops\":",
    "\"ops\": []",
    "\"ops\": [3]",
    "\"buckets\": [-1]",
    "\"shape\": [[]]",
    "null",
    "1e999",
    "18446744073709551616",
    "{",
    "}",
    "[",
    "\"",
    "\\u0000",
];

/// 1–3 structured byte-level mutations of an encoded artifact.
fn mutate_bytes(base: &[u8], rng: &mut Rng) -> Vec<u8> {
    let mut bytes = base.to_vec();
    for _ in 0..(1 + rng.below(3)) {
        match rng.below(6) {
            // truncate at an arbitrary byte
            0 => {
                if !bytes.is_empty() {
                    bytes.truncate(rng.below(bytes.len()));
                }
            }
            // flip one bit of one byte
            1 => {
                if !bytes.is_empty() {
                    let i = rng.below(bytes.len());
                    bytes[i] ^= 1 << rng.below(8);
                }
            }
            // zero a range (fakes padded/cleared payload sections)
            2 => {
                if !bytes.is_empty() {
                    let i = rng.below(bytes.len());
                    let j = (i + 1 + rng.below(32)).min(bytes.len());
                    for b in &mut bytes[i..j] {
                        *b = 0;
                    }
                }
            }
            // duplicate a chunk in place (desynchronizes length fields)
            3 => {
                if !bytes.is_empty() {
                    let i = rng.below(bytes.len());
                    let j = (i + 1 + rng.below(16)).min(bytes.len());
                    let chunk = bytes[i..j].to_vec();
                    let at = rng.below(bytes.len() + 1);
                    bytes.splice(at..at, chunk);
                }
            }
            // splice random bytes
            4 => {
                let at = rng.below(bytes.len() + 1);
                let extra: Vec<u8> = (0..1 + rng.below(8)).map(|_| rng.below(256) as u8).collect();
                bytes.splice(at..at, extra);
            }
            // extend past the declared end (trailing garbage)
            _ => {
                for _ in 0..1 + rng.below(16) {
                    bytes.push(rng.below(256) as u8);
                }
            }
        }
    }
    bytes
}

/// 1–3 text mutations of a manifest JSON document.
fn mutate_text(base: &str, rng: &mut Rng) -> String {
    let mut bytes = base.as_bytes().to_vec();
    for _ in 0..(1 + rng.below(3)) {
        match rng.below(4) {
            0 => {
                if !bytes.is_empty() {
                    bytes.truncate(rng.below(bytes.len()));
                }
            }
            1 => {
                if !bytes.is_empty() {
                    let i = rng.below(bytes.len());
                    bytes[i] ^= 1 << rng.below(8);
                }
            }
            2 => {
                let tok = DICT[rng.below(DICT.len())];
                let i = rng.below(bytes.len() + 1);
                let mut spliced = bytes[..i].to_vec();
                spliced.extend_from_slice(tok.as_bytes());
                spliced.push(b' ');
                spliced.extend_from_slice(&bytes[i..]);
                bytes = spliced;
            }
            _ => {
                let text = String::from_utf8_lossy(&bytes).into_owned();
                let mut lines: Vec<&str> = text.lines().collect();
                if lines.len() > 1 {
                    lines.remove(rng.below(lines.len()));
                }
                bytes = lines.join("\n").into_bytes();
            }
        }
    }
    String::from_utf8_lossy(&bytes).into_owned()
}

fn main() {
    let args = Args::from_env().expect("args");
    let iters = args.get_usize("iters").expect("--iters").unwrap_or(10_000);
    let seed = args.get_usize("seed").expect("--seed").map(|s| s as u64).unwrap_or(1);
    let bins = binary_corpus();
    let manifests = manifest_corpus();
    // sanity: every corpus entry must decode cleanly before mutation —
    // a fuzzer whose seeds are already rejected probes nothing
    for (k, b) in bins.iter().enumerate() {
        PlanArtifact::decode(b).unwrap_or_else(|e| panic!("corpus artifact {k} rejected: {e}"));
    }
    for (k, m) in manifests.iter().enumerate() {
        Manifest::from_json_text(m).unwrap_or_else(|e| panic!("corpus manifest {k} rejected: {e}"));
    }
    let (mut decoded_ok, mut rejected) = (0u64, 0u64);
    for i in 0..iters {
        let mut rng = Rng::new(seed).fork(i as u64);
        // alternate targets so one seed sweeps both parsers
        let outcome = if i % 2 == 0 {
            let input = mutate_bytes(&bins[rng.below(bins.len())], &mut rng);
            panic::catch_unwind(move || PlanArtifact::decode(&input).is_ok())
        } else {
            let input = mutate_text(&manifests[rng.below(manifests.len())], &mut rng);
            panic::catch_unwind(move || Manifest::from_json_text(&input).is_ok())
        };
        match outcome {
            Ok(true) => decoded_ok += 1,
            Ok(false) => rejected += 1,
            Err(_) => {
                eprintln!(
                    "fuzz-plan: PANIC at iteration {i} (seed {seed}, target {})",
                    if i % 2 == 0 { "artifact" } else { "manifest" }
                );
                std::process::exit(1);
            }
        }
    }
    println!(
        "fuzz-plan: {iters} iterations, seed {seed}: {decoded_ok} decoded, {rejected} rejected, \
         0 panics"
    );
}
