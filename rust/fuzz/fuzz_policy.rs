//! Deterministic structured fuzzer for the policy-JSON load path
//! (`util::json::parse` → `TrainedPolicy::from_json`), ISSUE 6
//! satellite. Zero dependencies: seeded by
//! [`precision_autotune::util::rng::Rng`], it mutates valid policy
//! artifacts — truncation, byte flips, splices of NaN/inf spellings
//! and structural tokens — and asserts the loader **errors, never
//! panics** and never hands back a policy holding non-finite Q values
//! or invalid visit counts.
//!
//! Usage: `cargo run --release --bin fuzz-policy -- [--iters 10000] [--seed 1]`

use std::panic;

use precision_autotune::bandit::action::ActionSpace;
use precision_autotune::bandit::qtable::QTable;
use precision_autotune::bandit::TrainedPolicy;
use precision_autotune::features::{Binner, Discretizer};
use precision_autotune::util::cli::Args;
use precision_autotune::util::json;
use precision_autotune::util::rng::Rng;

/// Tokens that probe the hardened deserialization paths: non-finite
/// number spellings (raw and the writer's escaped forms), out-of-range
/// literals, structural JSON noise, and schema keywords.
const DICT: &[&str] = &[
    "NaN",
    "Infinity",
    "-Infinity",
    "1e999",
    "-1e999",
    "\"__nan__\"",
    "\"__inf__\"",
    "\"__-inf__\"",
    "{",
    "}",
    "[",
    "]",
    ",",
    ":",
    "null",
    "true",
    "\"schema_version\"",
    "\"q\"",
    "\"visits\"",
    "\"lu-ir\"",
    "\"qr-ir\"",
    "-1",
    "0.5",
    "18446744073709551616",
    // v3 vocabulary: preconditioner names, restart field, decay axis
    "\"precond\"",
    "\"restart_m\"",
    "\"none\"",
    "\"jacobi\"",
    "\"block-jacobi\"",
    "\"ssor\"",
    "\"decay_lo\"",
    "\"decay_hi\"",
    "\"decay_bins\"",
];

/// Valid policy artifacts: the committed golden fixture (when the repo
/// layout is reachable) plus two crafted in-memory policies serialized
/// by the real writer, so the corpus always matches the current schema.
fn corpus() -> Vec<String> {
    let discretizer = |bins: usize| Discretizer {
        kappa: Binner { lo: 0.0, hi: 5.0, n_bins: bins },
        norm: Binner { lo: -1.0, hi: 1.0, n_bins: 1 },
        decay: Binner { lo: -16.0, hi: 0.0, n_bins: 1 },
        delta_c: 1.0,
        delta_n: 1e-30,
    };
    let mut small = QTable::new(2, ActionSpace::reduced_top_k(3));
    small.update(0, 1, 2.5, 1.0);
    small.update(1, 0, -0.75, 0.5);
    // precond-grown space: the serialized corpus carries non-trivial
    // precond/restart_m columns, so mutations probe the v3 decode paths
    let mut ext = QTable::new(1, ActionSpace::extended_precond_top_k(4));
    ext.update(0, ext.space.len() - 1, 1.25, 1.0);
    let mut c = vec![
        TrainedPolicy { qtable: small, discretizer: discretizer(2) }.to_json().to_string(),
        TrainedPolicy { qtable: ext, discretizer: discretizer(1) }.to_json().to_string(),
    ];
    let golden = concat!(env!("CARGO_MANIFEST_DIR"), "/../testdata/policy_golden_v3.json");
    if let Ok(text) = std::fs::read_to_string(golden) {
        c.push(text);
    }
    c
}

/// Apply 1–3 structured mutations (same repertoire as fuzz-mtx minus
/// line games — JSON is one line — plus digit rewrites that keep the
/// text parseable while corrupting values).
fn mutate(base: &str, rng: &mut Rng) -> String {
    let mut bytes = base.as_bytes().to_vec();
    for _ in 0..(1 + rng.below(3)) {
        match rng.below(5) {
            0 => {
                if !bytes.is_empty() {
                    bytes.truncate(rng.below(bytes.len()));
                }
            }
            1 => {
                if !bytes.is_empty() {
                    let i = rng.below(bytes.len());
                    bytes[i] ^= 1 << rng.below(8);
                }
            }
            2 => {
                let tok = DICT[rng.below(DICT.len())];
                let i = rng.below(bytes.len() + 1);
                let mut spliced = bytes[..i].to_vec();
                spliced.extend_from_slice(tok.as_bytes());
                spliced.extend_from_slice(&bytes[i..]);
                bytes = spliced;
            }
            // rewrite one digit (valid JSON, corrupted value: a shape
            // mismatch, a fractional visit count, a wrong version)
            3 => {
                let digits: Vec<usize> =
                    (0..bytes.len()).filter(|&i| bytes[i].is_ascii_digit()).collect();
                if !digits.is_empty() {
                    let i = digits[rng.below(digits.len())];
                    bytes[i] = b'0' + rng.below(10) as u8;
                }
            }
            // swap two bytes (reorders punctuation or digits)
            _ => {
                if bytes.len() > 1 {
                    let i = rng.below(bytes.len());
                    let j = rng.below(bytes.len());
                    bytes.swap(i, j);
                }
            }
        }
    }
    String::from_utf8_lossy(&bytes).into_owned()
}

/// Load the mutated text end to end. Returns whether a policy came
/// back; panics (the bug being hunted) propagate to the catch_unwind
/// in main. A policy that loads with a non-finite Q value would be a
/// hardening bypass — asserted here so the fuzzer catches it as a
/// crash rather than silently counting it as "parsed".
fn load(text: &str) -> bool {
    let Ok(v) = json::parse(text) else { return false };
    let Ok(policy) = TrainedPolicy::from_json(&v) else { return false };
    for s in 0..policy.qtable.n_states {
        for a in 0..policy.qtable.space.len() {
            assert!(
                policy.qtable.q(s, a).is_finite(),
                "loaded policy carries non-finite Q[{s},{a}]"
            );
        }
    }
    true
}

fn main() {
    let args = Args::from_env().expect("args");
    let iters = args.get_usize("iters").expect("--iters").unwrap_or(10_000);
    let seed = args.get_usize("seed").expect("--seed").map(|s| s as u64).unwrap_or(1);
    let corpus = corpus();
    let mut parsed_ok = 0u64;
    let mut rejected = 0u64;
    for i in 0..iters {
        let mut rng = Rng::new(seed).fork(i as u64);
        let base = &corpus[rng.below(corpus.len())];
        let input = mutate(base, &mut rng);
        match panic::catch_unwind(|| load(&input)) {
            Ok(true) => parsed_ok += 1,
            Ok(false) => rejected += 1,
            Err(_) => {
                eprintln!(
                    "fuzz-policy: PANIC at iteration {i} (seed {seed})\n--- input ---\n{input:?}"
                );
                std::process::exit(1);
            }
        }
    }
    println!(
        "fuzz-policy: {iters} iterations, seed {seed}: {parsed_ok} loaded, {rejected} rejected, \
         0 panics"
    );
}
