//! Deterministic structured fuzzer for the Matrix Market loader
//! (`util::mtx::parse_system`), ISSUE 6 satellite. Zero dependencies:
//! seeded by [`precision_autotune::util::rng::Rng`], it mutates valid
//! fixtures — truncation, byte flips, dictionary splices, line
//! shuffles — and asserts the loader **errors, never panics**. Every
//! run with the same `--seed` replays the identical input sequence, so
//! a crash report is a one-line repro.
//!
//! Usage: `cargo run --release --bin fuzz-mtx -- [--iters 10000] [--seed 1]`
//!
//! Exit status: 0 when every iteration returned (Ok or Err); 1 with
//! the offending seed/iteration/input printed when the parser panicked.

use std::panic;

use precision_autotune::util::cli::Args;
use precision_autotune::util::mtx;
use precision_autotune::util::rng::Rng;

/// Tokens that probe the paths hardened in ISSUE 6: non-finite value
/// spellings, out-of-range literals, header keywords (splicing one
/// mid-data desynchronizes the token cursor), and oversized counts.
const DICT: &[&str] = &[
    "nan",
    "NaN",
    "inf",
    "-inf",
    "Infinity",
    "1e999",
    "-1e999",
    "1e-999",
    "%%MatrixMarket",
    "matrix",
    "coordinate",
    "array",
    "pattern",
    "symmetric",
    "skew-symmetric",
    "general",
    "18446744073709551616",
    "0",
    "-1",
    "99999999",
    "%",
];

/// Valid seed inputs covering every storage/field/symmetry combination
/// the loader supports, plus the committed SPD sample when the repo
/// layout is available (binary run from an arbitrary cwd still works).
fn corpus() -> Vec<String> {
    let mut c = vec![
        "%%MatrixMarket matrix coordinate real general\n% comment\n3 3 4\n1 1 2.0\n2 2 3.0\n\
         3 3 4.0\n1 3 -1.5\n"
            .to_string(),
        "%%MatrixMarket matrix coordinate real symmetric\n3 3 4\n1 1 4.0\n2 1 -1.0\n2 2 4.0\n\
         3 3 4.0\n"
            .to_string(),
        "%%MatrixMarket matrix coordinate real skew-symmetric\n2 2 1\n2 1 5.0\n".to_string(),
        "%%MatrixMarket matrix coordinate pattern general\n2 2 2\n1 2\n2 1\n".to_string(),
        "%%MatrixMarket matrix coordinate integer general\n2 2 2\n1 1 7\n2 2 -3\n".to_string(),
        "%%MatrixMarket matrix array real general\n2 3\n1.0\n2.0\n3.0\n4.0\n5.0\n6.0\n".to_string(),
        "%%MatrixMarket matrix array real symmetric\n3 3\n1.0\n2.0\n3.0\n4.0\n5.0\n6.0\n"
            .to_string(),
        "%%MatrixMarket matrix array real general\n3 1\n1.5\n-2.5\n0.5\n".to_string(),
    ];
    let sample = concat!(env!("CARGO_MANIFEST_DIR"), "/../testdata/sample_spd.mtx");
    if let Ok(text) = std::fs::read_to_string(sample) {
        c.push(text);
    }
    c
}

/// Apply 1–3 structured mutations. Mutations operate on bytes and are
/// repaired with `from_utf8_lossy`, so multi-byte corruption degrades
/// to replacement characters instead of skipping the iteration.
fn mutate(base: &str, rng: &mut Rng) -> String {
    let mut bytes = base.as_bytes().to_vec();
    for _ in 0..(1 + rng.below(3)) {
        match rng.below(6) {
            // truncate at an arbitrary byte
            0 => {
                if !bytes.is_empty() {
                    bytes.truncate(rng.below(bytes.len()));
                }
            }
            // flip one bit of one byte
            1 => {
                if !bytes.is_empty() {
                    let i = rng.below(bytes.len());
                    bytes[i] ^= 1 << rng.below(8);
                }
            }
            // splice a dictionary token at a random position
            2 => {
                let tok = DICT[rng.below(DICT.len())];
                let i = rng.below(bytes.len() + 1);
                let mut spliced = bytes[..i].to_vec();
                spliced.extend_from_slice(tok.as_bytes());
                spliced.push(b' ');
                spliced.extend_from_slice(&bytes[i..]);
                bytes = spliced;
            }
            // duplicate a random line
            3 => {
                let text = String::from_utf8_lossy(&bytes).into_owned();
                let mut lines: Vec<&str> = text.lines().collect();
                if !lines.is_empty() {
                    let i = rng.below(lines.len());
                    let dup = lines[i];
                    lines.insert(i, dup);
                }
                bytes = (lines.join("\n") + "\n").into_bytes();
            }
            // delete a random line (drops the size line, a data row, ...)
            4 => {
                let text = String::from_utf8_lossy(&bytes).into_owned();
                let mut lines: Vec<&str> = text.lines().collect();
                if lines.len() > 1 {
                    lines.remove(rng.below(lines.len()));
                }
                bytes = (lines.join("\n") + "\n").into_bytes();
            }
            // shuffle the data lines (header kept, so parsing gets deep)
            _ => {
                let text = String::from_utf8_lossy(&bytes).into_owned();
                let mut lines: Vec<&str> = text.lines().collect();
                if lines.len() > 2 {
                    let tail = &mut lines[1..];
                    rng.shuffle(tail);
                }
                bytes = (lines.join("\n") + "\n").into_bytes();
            }
        }
    }
    String::from_utf8_lossy(&bytes).into_owned()
}

fn main() {
    let args = Args::from_env().expect("args");
    let iters = args.get_usize("iters").expect("--iters").unwrap_or(10_000);
    let seed = args.get_usize("seed").expect("--seed").map(|s| s as u64).unwrap_or(1);
    let corpus = corpus();
    let mut parsed_ok = 0u64;
    let mut rejected = 0u64;
    for i in 0..iters {
        let mut rng = Rng::new(seed).fork(i as u64);
        let base = &corpus[rng.below(corpus.len())];
        let input = mutate(base, &mut rng);
        match panic::catch_unwind(|| mtx::parse_system(&input).is_ok()) {
            Ok(true) => parsed_ok += 1,
            Ok(false) => rejected += 1,
            Err(_) => {
                eprintln!(
                    "fuzz-mtx: PANIC at iteration {i} (seed {seed})\n--- input ---\n{input:?}"
                );
                std::process::exit(1);
            }
        }
    }
    println!(
        "fuzz-mtx: {iters} iterations, seed {seed}: {parsed_ok} parsed, {rejected} rejected, \
         0 panics"
    );
}
