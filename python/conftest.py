import os
import sys

import jax

sys.path.insert(0, os.path.dirname(__file__))
jax.config.update("jax_enable_x64", True)
