"""Full GMRES-IR composition (jax mirror of the Rust driver): the paper's
qualitative claims at solver level."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def make(n, kappa, seed):
    """Small randsvd-mode-2 style system (n-1 singular values at sigma_max,
    one at sigma_max/kappa) — same construction as paper eq. (31)."""
    rng = np.random.default_rng(seed)
    q1, _ = np.linalg.qr(rng.standard_normal((n, n)))
    q2, _ = np.linalg.qr(rng.standard_normal((n, n)))
    s = np.ones(n)
    s[-1] = 1.0 / kappa
    a = (q1 * s) @ q2.T
    xt = rng.standard_normal(n)
    return a, xt, a @ xt


@pytest.mark.parametrize("kappa", [1e2, 1e6])
def test_fp64_action_reaches_working_accuracy(kappa):
    a, xt, b = make(48, kappa, 0)
    x, outer, inner, ok = model.gmres_ir_reference(
        jnp.asarray(a), jnp.asarray(b), ("fp64", "fp64", "fp64", "fp64")
    )
    assert ok
    ferr = np.max(np.abs(np.asarray(x) - xt)) / np.max(np.abs(xt))
    assert ferr < 1e-9 * kappa
    assert outer <= 5  # converges or stagnates quickly at fp64


def test_low_precision_factorization_still_converges_when_well_conditioned():
    """Paper's central premise: u_f can be low for small kappa (GMRES-IR
    [10,11]) — bf16 LU + fp64 residual recovers fp64-level accuracy."""
    a, xt, b = make(48, 1e2, 1)
    x, outer, inner, ok = model.gmres_ir_reference(
        jnp.asarray(a), jnp.asarray(b), ("bf16", "fp64", "fp32", "fp64"),
        tol_gmres=1e-6, max_outer=10,
    )
    assert ok
    ferr = np.max(np.abs(np.asarray(x) - xt)) / np.max(np.abs(xt))
    assert ferr < 1e-10
    assert outer >= 2  # must actually refine


def test_low_precision_everywhere_loses_accuracy():
    """All-bf16 action cannot reach fp64 accuracy — the trade-off the RL
    agent's reward navigates."""
    a, xt, b = make(48, 1e2, 2)
    x, outer, inner, ok = model.gmres_ir_reference(
        jnp.asarray(a), jnp.asarray(b), ("bf16", "bf16", "bf16", "bf16"),
        tol_gmres=1e-2, max_outer=6,
    )
    ferr = np.max(np.abs(np.asarray(x) - xt)) / np.max(np.abs(xt))
    assert ferr > 1e-8  # far from fp64-level


def test_monotone_action_accuracy_ordering():
    a, xt, b = make(40, 1e3, 3)
    def ferr_of(fmts):
        x, *_ = model.gmres_ir_reference(
            jnp.asarray(a), jnp.asarray(b), fmts, tol_gmres=1e-8, max_outer=8
        )
        return np.max(np.abs(np.asarray(x) - xt)) / np.max(np.abs(xt))
    full = ferr_of(("fp64", "fp64", "fp64", "fp64"))
    mixed = ferr_of(("fp32", "fp64", "fp64", "fp64"))
    assert full <= 1e-12
    assert mixed <= 1e-10  # refinement recovers despite fp32 factorization
