"""Pallas chopped-GEMV / outer-update kernels vs the numpy oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.chop import (
    EXPERIMENT_FORMATS,
    pallas_chopped_matvec,
    pallas_outer_update,
)
from compile.kernels.ref import chop_ref, chopped_matvec_perop_ref, chopped_matvec_ref


@settings(max_examples=30, deadline=None)
@given(
    st.integers(1, 200),
    st.integers(1, 200),
    st.sampled_from(EXPERIMENT_FORMATS),
    st.integers(0, 2**32 - 1),
)
def test_matvec_matches_oracle(m, n, fmt, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, n)) * np.exp(rng.uniform(-5, 5))
    x = rng.standard_normal(n)
    got = np.asarray(pallas_chopped_matvec(jnp.asarray(a), jnp.asarray(x), fmt))
    want = chopped_matvec_ref(a, x, fmt)
    if fmt == "fp64":
        # No final quantization: blockwise summation order may differ.
        np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-300)
    else:
        # For n <= one column block the accumulation order is identical
        # and the final chop quantizes: exact equality required.
        if n <= 128:
            assert np.array_equal(got, want), fmt
        else:
            scale = np.max(np.abs(want)) + 1e-300
            np.testing.assert_allclose(got, want, rtol=0, atol=2 ** -7 * scale)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 24), st.sampled_from(["bf16", "tf32", "fp32"]), st.integers(0, 2**32 - 1))
def test_accum_mode_close_to_perop_mode(n, fmt, seed):
    """DESIGN.md §5 fidelity note: f64-accumulate emulation stays within a
    few target ulps of strict Pychop per-op rounding for small dots."""
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n))
    x = rng.standard_normal(n)
    fast = chopped_matvec_ref(a, x, fmt)
    strict = chopped_matvec_perop_ref(a, x, fmt)
    from compile.kernels.chop import FORMATS

    u = 2.0 ** (-FORMATS[fmt].t)
    scale = np.abs(a).sum(axis=1) * np.abs(x).max() + 1e-30
    assert np.all(np.abs(fast - strict) <= 4 * n * u * scale)


@settings(max_examples=25, deadline=None)
@given(
    st.integers(1, 150),
    st.integers(1, 150),
    st.sampled_from(EXPERIMENT_FORMATS),
    st.integers(0, 2**32 - 1),
)
def test_outer_update_matches_oracle(m, n, fmt, seed):
    rng = np.random.default_rng(seed)
    a = chop_ref(rng.standard_normal((m, n)), fmt)
    mc = chop_ref(rng.standard_normal(m), fmt)
    rr = chop_ref(rng.standard_normal(n), fmt)
    got = np.asarray(
        pallas_outer_update(jnp.asarray(mc), jnp.asarray(rr), jnp.asarray(a), fmt)
    )
    if fmt == "fp64":
        want = a - np.outer(mc, rr)
        # XLA may fuse a - m*r into an FMA: under cancellation the relative
        # gap is unbounded, so compare against the operand magnitude.
        scale = np.abs(a) + np.abs(np.outer(mc, rr)) + 1e-300
        assert np.all(np.abs(got - want) <= 1e-15 * scale)
    else:
        want = chop_ref(a - chop_ref(np.outer(mc, rr), fmt), fmt)
        assert np.array_equal(got, want)
