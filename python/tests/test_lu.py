"""L2 lu_factor / lu_solve graphs: fp64 path vs oracle; chopped paths obey
the classic error scaling; failure flag trips on singular input."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import lu_ref, lu_solve_ref


def random_system(n, seed, diag_boost=None):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n))
    if diag_boost:
        a += diag_boost * np.eye(n)
    xt = rng.standard_normal(n)
    return a, xt, a @ xt


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 60), st.integers(0, 2**32 - 1))
def test_fp64_lu_matches_oracle(n, seed):
    a, _, _ = random_system(n, seed)
    lu, piv, ok = model.lu_factor(jnp.asarray(a), "fp64")
    assert int(ok) == 1
    lu_want, piv_want = lu_ref(a)
    np.testing.assert_allclose(np.asarray(lu), lu_want, rtol=1e-12, atol=1e-13)
    assert np.array_equal(np.asarray(piv), piv_want)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 60), st.integers(0, 2**32 - 1))
def test_fp64_lu_solve_solves(n, seed):
    a, xt, b = random_system(n, seed, diag_boost=n)
    lu, piv, ok = model.lu_factor(jnp.asarray(a), "fp64")
    x = np.asarray(model.lu_solve(lu, piv, jnp.asarray(b), "fp64"))
    assert int(ok) == 1
    np.testing.assert_allclose(x, xt, rtol=1e-9)


def test_fp64_solve_matches_reference_solver():
    a, xt, b = random_system(40, 7, diag_boost=40)
    lu_w, piv_w = lu_ref(a)
    x_w = lu_solve_ref(lu_w, piv_w, b)
    lu, piv, _ = model.lu_factor(jnp.asarray(a), "fp64")
    x = np.asarray(model.lu_solve(lu, piv, jnp.asarray(b), "fp64"))
    np.testing.assert_allclose(x, x_w, rtol=1e-11)


@pytest.mark.parametrize("fmt,tol", [("bf16", 5e-2), ("tf32", 5e-3), ("fp32", 5e-6)])
def test_chopped_lu_error_scaling(fmt, tol):
    """ferr of a one-shot chopped solve scales with the format's unit
    roundoff (well-conditioned system => ferr ~ c_n * u_fmt)."""
    a, xt, b = random_system(64, 3, diag_boost=64)
    lu, piv, ok = model.lu_factor(jnp.asarray(a), fmt)
    assert int(ok) == 1
    x = np.asarray(model.lu_solve(lu, piv, jnp.asarray(b), fmt))
    ferr = np.max(np.abs(x - xt)) / np.max(np.abs(xt))
    assert 0 < ferr < tol, (fmt, ferr)


def test_error_ordering_across_formats():
    a, xt, b = random_system(80, 11, diag_boost=80)
    errs = {}
    for fmt in ("bf16", "fp32", "fp64"):
        lu, piv, _ = model.lu_factor(jnp.asarray(a), fmt)
        x = np.asarray(model.lu_solve(lu, piv, jnp.asarray(b), fmt))
        errs[fmt] = np.max(np.abs(x - xt)) / np.max(np.abs(xt))
    assert errs["fp64"] < errs["fp32"] < errs["bf16"]


def test_singular_matrix_sets_failure_flag():
    a = np.zeros((8, 8))
    _, _, ok = model.lu_factor(jnp.asarray(a), "fp64")
    assert int(ok) == 0


def test_overflow_in_narrow_format_sets_failure_flag():
    """bf16 overflows beyond ~3.4e38: a matrix scaled past xmax chops to
    inf and the pivot check must trip."""
    a = np.eye(8) * 1e39
    _, _, ok = model.lu_factor(jnp.asarray(a), "bf16")
    assert int(ok) == 0


def test_pivoting_handles_zero_leading_entry():
    a = np.array([[0.0, 1.0], [1.0, 0.0]])
    lu, piv, ok = model.lu_factor(jnp.asarray(a), "fp64")
    assert int(ok) == 1
    x = np.asarray(model.lu_solve(lu, piv, jnp.asarray([2.0, 3.0]), "fp64"))
    np.testing.assert_allclose(x, [3.0, 2.0])
