"""Kernel-vs-oracle tests for the chop emulator (L1).

The bit-twiddling kernel (``chop.chop_bits`` / ``chop.pallas_chop``) must
agree *bit-for-bit* with the independent frexp-based oracle
(``ref.chop_ref``) on every format of paper Table 1, including subnormals,
ties, overflow and specials. Hypothesis drives the sweep.
"""

import json
import os
import struct

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.chop import FORMATS, chop_bits, pallas_chop
from compile.kernels.ref import chop_ref

ALL_FMTS = list(FORMATS)


def bits_equal(a, b):
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    return np.array_equal(
        a.view(np.uint64), b.view(np.uint64)
    ) or np.array_equal(np.where(np.isnan(a), 0, a), np.where(np.isnan(b), 0, b))


@pytest.mark.parametrize("fmt", ALL_FMTS)
def test_exact_values_table1(fmt):
    f = FORMATS[fmt]
    # unit roundoff u = 2^-t; 1 + u must round back to 1 (tie to even),
    # 1 + 2u must survive (it is the next representable number... for
    # formats with t bits, spacing at 1.0 is 2^{1-t} = 2u).
    u = 2.0 ** (-f.t)
    assert float(chop_ref(np.array([1.0 + u]), f)[0]) == 1.0  # RNE tie -> even
    assert float(chop_ref(np.array([1.0 + 2 * u]), f)[0]) == 1.0 + 2 * u
    assert float(chop_ref(np.array([1.0 + 3 * u]), f)[0]) == 1.0 + 4 * u
    # xmax is preserved; anything above rounds to inf eventually
    assert float(chop_ref(np.array([f.xmax]), f)[0]) == f.xmax
    # 1.1*xmax rounds above xmax for every format (incl. e4m3, whose xmax
    # 448 is below the standard formula because the top code is NaN).
    assert np.isinf(chop_ref(np.array([f.xmax * 1.1]), f)[0])
    # smallest normal is preserved
    xmin = 2.0**f.emin
    assert float(chop_ref(np.array([xmin]), f)[0]) == xmin


@pytest.mark.parametrize("fmt", ALL_FMTS)
def test_specials(fmt):
    x = np.array([0.0, -0.0, np.inf, -np.inf, np.nan])
    for impl in (lambda v: np.asarray(chop_bits(jnp.asarray(v), FORMATS[fmt])),
                 lambda v: chop_ref(v, fmt)):
        y = impl(x)
        assert y[0] == 0.0 and not np.signbit(y[0])
        assert y[1] == 0.0 and np.signbit(y[1])
        assert np.isposinf(y[2]) and np.isneginf(y[3]) and np.isnan(y[4])


@settings(max_examples=300, deadline=None)
@given(
    st.floats(allow_nan=True, allow_infinity=True, allow_subnormal=True),
    st.sampled_from(ALL_FMTS),
)
def test_kernel_matches_oracle_scalar(x, fmt):
    got = np.asarray(chop_bits(jnp.float64(x), FORMATS[fmt]))
    want = chop_ref(np.array([x]), fmt)[0]
    assert bits_equal(got, want), (x, fmt, got, want)


@settings(max_examples=50, deadline=None)
@given(
    st.lists(
        st.floats(allow_nan=False, allow_infinity=True, allow_subnormal=True),
        min_size=1,
        max_size=300,
    ),
    st.sampled_from(ALL_FMTS),
)
def test_pallas_matches_oracle_vectors(xs, fmt):
    x = np.array(xs, dtype=np.float64)
    got = np.asarray(pallas_chop(jnp.asarray(x), fmt))
    want = chop_ref(x, fmt)
    assert bits_equal(got, want), (fmt,)


@settings(max_examples=25, deadline=None)
@given(
    st.integers(1, 40),
    st.integers(1, 40),
    st.sampled_from(ALL_FMTS),
    st.integers(0, 2**32 - 1),
)
def test_pallas_matches_oracle_matrices(m, n, fmt, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, n)) * np.exp(rng.uniform(-30, 30, (m, n)))
    got = np.asarray(pallas_chop(jnp.asarray(x), fmt))
    want = chop_ref(x, fmt)
    assert bits_equal(got, want)


@settings(max_examples=200, deadline=None)
@given(
    st.floats(allow_nan=False, allow_infinity=False, allow_subnormal=True),
    st.sampled_from(ALL_FMTS),
)
def test_idempotent(x, fmt):
    once = chop_ref(np.array([x]), fmt)
    twice = chop_ref(once, fmt)
    assert bits_equal(once, twice)


@settings(max_examples=200, deadline=None)
@given(
    st.floats(-1e30, 1e30),
    st.floats(-1e30, 1e30),
    st.sampled_from(ALL_FMTS),
)
def test_monotone(a, b, fmt):
    lo, hi = min(a, b), max(a, b)
    y = chop_ref(np.array([lo, hi]), fmt)
    assert y[0] <= y[1]


@settings(max_examples=200, deadline=None)
@given(st.floats(-1e37, 1e37, allow_subnormal=False))
def test_widening_chain(x):
    """chop through a wider format first never changes the narrow result
    when the wide format's grid is a superset (fp32 -> bf16 shares emin)."""
    via = chop_ref(chop_ref(np.array([x]), "fp32"), "bf16")
    direct = chop_ref(np.array([x]), "bf16")
    # Not exactly equal in general (double rounding), but ties aside the
    # relative gap is bounded by one bf16 ulp.
    if np.isfinite(via[0]) and np.isfinite(direct[0]) and direct[0] != 0:
        assert abs(via[0] - direct[0]) <= 2.0 ** (-7) * abs(direct[0])


def test_relative_error_bound():
    """|chop(x) - x| <= u |x| with u = 2^-t, for normal-range x."""
    rng = np.random.default_rng(42)
    for fmt in ALL_FMTS:
        f = FORMATS[fmt]
        x = rng.standard_normal(5000) * np.exp(rng.uniform(-3, 3, 5000))
        # The u-bound only holds in the normal range of the format
        # (subnormals have larger relative spacing).
        x = x[np.abs(x) >= 2.0**f.emin]
        y = chop_ref(x, fmt)
        rel = np.abs(y - x) / np.abs(x)
        assert rel.max() <= 2.0 ** (-f.t), fmt


def test_golden_vectors():
    """Cross-language ground truth shared with the Rust chop module."""
    path = os.path.join(os.path.dirname(__file__), "..", "..", "testdata", "chop_golden.json")
    with open(path) as fh:
        golden = json.load(fh)
    for case in golden["cases"]:
        x = struct.unpack("<d", bytes.fromhex(case["x"]))[0]
        for fmt, want_hex in case["out"].items():
            got = chop_ref(np.array([x]), fmt)[0]
            got_hex = struct.pack("<d", got).hex()
            assert got_hex == want_hex, (case["x"], fmt)
