"""L2 preconditioned-GMRES graph: convergence, tolerance honoring,
breakdown handling, chopped-precision behaviour."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def setup(n, seed, diag=None, fmt="fp64"):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n)) + (diag if diag else n) * np.eye(n)
    xt = rng.standard_normal(n)
    b = a @ xt
    lu, piv, ok = model.lu_factor(jnp.asarray(a), fmt)
    assert int(ok) == 1
    return a, xt, b, lu, piv


def run_gmres(a, lu, piv, r, fmt, tol=1e-10, maxit=50):
    return model.gmres(
        jnp.asarray(a), lu, piv, jnp.asarray(r), jnp.float64(tol), jnp.int32(maxit), fmt
    )


def test_exact_preconditioner_converges_immediately():
    a, xt, b, lu, piv = setup(40, 0)
    z, it, relres, ok = run_gmres(a, lu, piv, b, "fp64")
    assert int(ok) == 1
    assert int(it) <= 2
    np.testing.assert_allclose(np.asarray(z), xt, rtol=1e-8)


def test_tolerance_is_honored():
    a, xt, b, lu, piv = setup(60, 1)
    for tol in (1e-4, 1e-8, 1e-12):
        z, it, relres, ok = run_gmres(a, lu, piv, b, "fp64", tol=tol)
        assert float(relres) <= tol or int(it) == 50


def test_maxit_caps_iterations():
    a, xt, b, lu, piv = setup(40, 2)
    # Make the preconditioner useless for the perturbed system so GMRES
    # needs several iterations, then cap them.
    a2 = a + 0.5 * np.random.default_rng(3).standard_normal(a.shape)
    z, it, relres, ok = model.gmres(
        jnp.asarray(a2), lu, piv, jnp.asarray(b), jnp.float64(1e-30), jnp.int32(3), "fp64"
    )
    assert int(it) <= 3


def test_identity_happy_breakdown():
    n = 16
    a = np.eye(n)
    lu, piv, ok = model.lu_factor(jnp.asarray(a), "fp64")
    b = np.arange(1.0, n + 1.0)
    z, it, relres, ok = run_gmres(a, lu, piv, b, "fp64")
    assert int(it) <= 2
    np.testing.assert_allclose(np.asarray(z), b, rtol=1e-12)


@pytest.mark.parametrize("fmt", ["bf16", "tf32", "fp32"])
def test_chopped_gmres_reduces_residual(fmt):
    a, xt, b, lu, piv = setup(48, 4, fmt=fmt)
    z, it, relres, ok = run_gmres(a, lu, piv, b, fmt, tol=1e-2)
    assert int(ok) == 1
    assert np.all(np.isfinite(np.asarray(z)))
    # solution should be in the right ballpark even at low precision
    rel = np.max(np.abs(np.asarray(z) - xt)) / np.max(np.abs(xt))
    assert rel < 0.2, (fmt, rel)


def test_zero_rhs_is_safe():
    a, xt, b, lu, piv = setup(20, 5)
    z, it, relres, ok = run_gmres(a, lu, piv, np.zeros(20), "fp64")
    assert np.all(np.isfinite(np.asarray(z)))
    assert np.allclose(np.asarray(z), 0.0)


def test_nan_rhs_flags_not_ok():
    a, xt, b, lu, piv = setup(20, 6)
    r = np.full(20, np.nan)
    z, it, relres, ok = run_gmres(a, lu, piv, r, "fp64")
    assert int(ok) == 0
