"""AOT lowering: entry coverage, HLO-text well-formedness, manifest."""

import json
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels.chop import FORMATS


def test_build_entries_coverage():
    entries = aot.build_entries((64, 128), ("bf16", "fp64"))
    names = {e["name"] for e in entries}
    for n in (64, 128):
        for f in ("bf16", "fp64"):
            for op in ("lu_factor", "lu_solve", "residual", "gmres"):
                assert f"{op}_{f}_{n}" in names
    # chop artifacts cover all 7 formats of Table 1
    for f in FORMATS:
        assert f"chop_{f}_{aot.CHOP_LEN}" in names
    assert len(entries) == 2 * 2 * 4 + len(FORMATS)


def test_hlo_text_emission():
    lowered = jax.jit(lambda a: model.lu_factor(a, "fp32")).lower(
        jax.ShapeDtypeStruct((16, 16), jnp.float64)
    )
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "HloModule" in text
    assert "f64[16,16]" in text
    # tuple return (rust side always unwraps a tuple)
    assert "(f64[16,16]" in text


def test_aot_main_writes_manifest():
    with tempfile.TemporaryDirectory() as td:
        res = subprocess.run(
            [
                sys.executable,
                "-m",
                "compile.aot",
                "--out",
                td,
                "--buckets",
                "16",
                "--formats",
                "fp32",
                "--only",
                "lu_solve_fp32_16,residual_fp32_16",
            ],
            cwd=os.path.join(os.path.dirname(__file__), ".."),
            capture_output=True,
            text=True,
            timeout=300,
        )
        assert res.returncode == 0, res.stderr
        with open(os.path.join(td, "manifest.json")) as fh:
            manifest = json.load(fh)
        assert {a["name"] for a in manifest["artifacts"]} == {
            "lu_solve_fp32_16",
            "residual_fp32_16",
        }
        art = manifest["artifacts"][0]
        assert os.path.exists(os.path.join(td, art["file"]))
        assert art["inputs"][0]["dtype"] in ("f64", "i32")


def test_manifest_records_gmres_buffer_size():
    entries = aot.build_entries((64,), ("fp64",))
    g = [e for e in entries if e["op"] == "gmres"][0]
    assert g["outputs"][0]["shape"] == [64]
    assert model.GMRES_MAX_M == 50
