"""AOT lowering: every (op, format, size-bucket) jax graph -> HLO text.

HLO *text* is the interchange format (NOT a serialized HloModuleProto):
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Usage (from the Makefile, cwd = python/):

    python -m compile.aot --out ../artifacts [--buckets 64,128,256,512]
                          [--formats bf16,tf32,fp32,fp64]

Writes ``<out>/<op>_<fmt>_<n>.hlo.txt`` plus ``<out>/manifest.json``
describing every artifact's I/O signature for the Rust runtime.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402
from .kernels.chop import EXPERIMENT_FORMATS, FORMATS, chop_bits  # noqa: E402

DEFAULT_BUCKETS = (64, 128, 256, 512)
CHOP_LEN = 4096  # standalone chop artifacts (cross-language validation)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side always unwraps a tuple, even for single outputs)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype="float64"):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def build_entries(buckets, formats):
    """Yield (name, lowered-fn-factory, input specs, output meta)."""
    entries = []
    for n in buckets:
        mat = _spec((n, n))
        vec = _spec((n,))
        ivec = _spec((n,), "int32")
        scal = _spec(())
        iscal = _spec((), "int32")
        for fmt in formats:
            entries.append(
                dict(
                    name=f"lu_factor_{fmt}_{n}",
                    op="lu_factor",
                    fmt=fmt,
                    n=n,
                    fn=lambda a, fmt=fmt: model.lu_factor(a, fmt),
                    in_specs=[mat],
                    in_names=["a"],
                    outputs=[
                        {"name": "lu", "shape": [n, n], "dtype": "f64"},
                        {"name": "piv", "shape": [n], "dtype": "i32"},
                        {"name": "ok", "shape": [], "dtype": "i32"},
                    ],
                )
            )
            entries.append(
                dict(
                    name=f"lu_solve_{fmt}_{n}",
                    op="lu_solve",
                    fmt=fmt,
                    n=n,
                    fn=lambda lu, piv, b, fmt=fmt: (model.lu_solve(lu, piv, b, fmt),),
                    in_specs=[mat, ivec, vec],
                    in_names=["lu", "piv", "b"],
                    outputs=[{"name": "x", "shape": [n], "dtype": "f64"}],
                )
            )
            entries.append(
                dict(
                    name=f"residual_{fmt}_{n}",
                    op="residual",
                    fmt=fmt,
                    n=n,
                    fn=lambda a, x, b, fmt=fmt: (model.residual(a, x, b, fmt),),
                    in_specs=[mat, vec, vec],
                    in_names=["a", "x", "b"],
                    outputs=[{"name": "r", "shape": [n], "dtype": "f64"}],
                )
            )
            entries.append(
                dict(
                    name=f"gmres_{fmt}_{n}",
                    op="gmres",
                    fmt=fmt,
                    n=n,
                    fn=lambda a, lu, piv, r, tol, maxit, fmt=fmt: model.gmres(
                        a, lu, piv, r, tol, maxit, fmt
                    ),
                    in_specs=[mat, mat, ivec, vec, scal, iscal],
                    in_names=["a", "lu", "piv", "r", "tol", "maxit"],
                    outputs=[
                        {"name": "z", "shape": [n], "dtype": "f64"},
                        {"name": "iters", "shape": [], "dtype": "i32"},
                        {"name": "relres", "shape": [], "dtype": "f64"},
                        {"name": "ok", "shape": [], "dtype": "i32"},
                    ],
                )
            )
    # Standalone chop artifacts over every format of Table 1: these are the
    # cross-language ground truth the Rust chop module is tested against.
    for fmt in FORMATS:
        entries.append(
            dict(
                name=f"chop_{fmt}_{CHOP_LEN}",
                op="chop",
                fmt=fmt,
                n=CHOP_LEN,
                fn=lambda x, fmt=fmt: (chop_bits(x, FORMATS[fmt]),),
                in_specs=[_spec((CHOP_LEN,))],
                in_names=["x"],
                outputs=[{"name": "y", "shape": [CHOP_LEN], "dtype": "f64"}],
            )
        )
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--buckets", default=",".join(map(str, DEFAULT_BUCKETS)))
    ap.add_argument("--formats", default=",".join(EXPERIMENT_FORMATS))
    ap.add_argument("--only", default="", help="comma list of artifact names")
    args = ap.parse_args()

    buckets = tuple(int(b) for b in args.buckets.split(",") if b)
    formats = tuple(f for f in args.formats.split(",") if f)
    for f in formats:
        if f not in FORMATS:
            raise SystemExit(f"unknown format {f!r}; known: {list(FORMATS)}")
    only = {s for s in args.only.split(",") if s}

    os.makedirs(args.out, exist_ok=True)
    manifest = {
        "version": 1,
        "gmres_max_m": model.GMRES_MAX_M,
        "buckets": list(buckets),
        "formats": list(formats),
        "artifacts": [],
    }
    t0 = time.time()
    entries = build_entries(buckets, formats)
    for e in entries:
        if only and e["name"] not in only:
            continue
        t1 = time.time()
        lowered = jax.jit(e["fn"]).lower(*e["in_specs"])
        text = to_hlo_text(lowered)
        fname = f"{e['name']}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": e["name"],
                "op": e["op"],
                "fmt": e["fmt"],
                "n": e["n"],
                "file": fname,
                "inputs": [
                    {
                        "name": nm,
                        "shape": list(sp.shape),
                        "dtype": "i32" if sp.dtype == jnp.int32 else "f64",
                    }
                    for nm, sp in zip(e["in_names"], e["in_specs"])
                ],
                "outputs": e["outputs"],
                "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            }
        )
        print(
            f"  lowered {e['name']:<24} {len(text):>9} chars  "
            f"({time.time() - t1:.1f}s)"
        )
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(
        f"wrote {len(manifest['artifacts'])} artifacts + manifest.json "
        f"to {args.out} in {time.time() - t0:.1f}s"
    )


if __name__ == "__main__":
    main()
