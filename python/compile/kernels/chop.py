"""Layer-1 Pallas kernels: floating-point format emulation ("chop").

This is the compute hot-spot of the paper's system: every mixed-precision
step of GMRES-IR (LU factorization, residual, inner GMRES) is simulated by
rounding f64 values to a target format (t significand bits, exponent range
[emin, emax]) with round-to-nearest-even, exactly like the paper's Pychop
emulation [Carson & Chen 2025].

Two kernels live here:

* ``pallas_chop``       — elementwise chop over tiled blocks.
* ``pallas_chopped_matvec`` — y = chop_fmt(A) @ chop_fmt(x) with f64
  accumulation per block and a final chop of the result (MXU-style
  low-precision-operand / high-precision-accumulate semantics; see
  DESIGN.md §3 Hardware adaptation).

The chop itself is implemented with *bit operations* (exponent extracted
from the IEEE-754 representation) so it is exact: dividing by a power of
two is exact in binary floating point, and ``jnp.round`` implements
ties-to-even. An independent frexp-based oracle lives in ``ref.py``; the
two are cross-checked by hypothesis sweeps in ``python/tests``.

Kernels are lowered with ``interpret=True`` (CPU PJRT cannot execute
Mosaic custom-calls); block shapes are nevertheless chosen for TPU VMEM:
(128, 128) f64 tiles = 128 KiB/operand, far under the ~16 MiB VMEM budget,
leaving room for double buffering.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


class Format(NamedTuple):
    """A floating-point format as in paper Table 1.

    t    -- significand bits including the implicit leading bit
    emin -- exponent of the smallest positive normalized number
    emax -- exponent of the largest finite number
    xmax -- largest finite value (usually (2 - 2^{1-t}) * 2^emax, but
            e.g. FP8-E4M3 reserves the top code for NaN => 448)
    """

    name: str
    t: int
    emin: int
    emax: int
    xmax: float


def _std_xmax(t: int, emax: int) -> float:
    return (2.0 - 2.0 ** (1 - t)) * (2.0**emax)


#: The seven formats of paper Table 1 (+ FP8 extension formats used in the
#: paper's introduction). Keys are the names used across the whole repo —
#: the Rust `chop` module mirrors this table bit-for-bit.
FORMATS: dict[str, Format] = {
    "bf16": Format("bf16", 8, -126, 127, _std_xmax(8, 127)),
    "fp16": Format("fp16", 11, -14, 15, _std_xmax(11, 15)),
    "tf32": Format("tf32", 11, -126, 127, _std_xmax(11, 127)),
    "fp32": Format("fp32", 24, -126, 127, _std_xmax(24, 127)),
    "fp64": Format("fp64", 53, -1022, 1023, _std_xmax(53, 1023)),
    "e4m3": Format("e4m3", 4, -6, 8, 448.0),
    "e5m2": Format("e5m2", 3, -14, 15, _std_xmax(3, 15)),
}

#: Precision set 𝒰 used in the paper's experiments (§5.1).
EXPERIMENT_FORMATS = ("bf16", "tf32", "fp32", "fp64")


def chop_bits(x: jax.Array, fmt: Format) -> jax.Array:
    """Exact round-to-nearest-even of f64 ``x`` into ``fmt``.

    Pure jnp (usable inside and outside Pallas kernels). Semantics:

    * normals: round the significand to ``t`` bits;
    * values below 2^emin: round onto the subnormal grid of quantum
      2^(emin - t + 1) (flush-to-zero happens naturally when the nearest
      grid point is 0);
    * overflow after rounding (|y| > xmax): +/-inf, as IEEE RNE demands;
    * inf/NaN/zero pass through (signed zeros preserved).
    """
    if fmt.name == "fp64":
        return x  # chop to the carrier format is the identity
    bits = lax.bitcast_convert_type(x, jnp.uint64)
    expf = ((bits >> jnp.uint64(52)) & jnp.uint64(0x7FF)).astype(jnp.int32)
    e = expf - 1023
    # f64 subnormal inputs (expf == 0) are < 2^-1022 <= 2^emin for every
    # target format: clamp their exponent so they land on the target's
    # subnormal grid (which rounds them to 0 for all formats of Table 1).
    e = jnp.where(expf == 0, -1023, e)
    e_eff = jnp.maximum(e, fmt.emin)
    # Quantum q = 2^(e_eff - t + 1), built from IEEE-754 bits: XLA lowers
    # exp2 through exp, which is NOT exact for integer arguments, and the
    # whole emulation hinges on q being an exact power of two.
    shift = e_eff - (fmt.t - 1)
    bits_normal = (shift + 1023).astype(jnp.uint64) << jnp.uint64(52)
    bits_subn = jnp.uint64(1) << jnp.clip(shift + 1074, 0, 63).astype(jnp.uint64)
    qbits = jnp.where(shift >= -1022, bits_normal, bits_subn)
    q = lax.bitcast_convert_type(qbits, jnp.float64)
    y = jnp.round(x / q) * q  # x/q and r*q exact; round() is ties-to-even
    # No explicit zero/inf/NaN passthrough is needed — the arithmetic path
    # already produces them exactly (0/q = +-0, inf/q = inf, NaN sticks;
    # for inf/NaN inputs expf = 0x7FF gives a huge-but-valid q). Avoiding
    # the select also sidesteps a Pallas-interpret miscompile observed for
    # selects guarded by uint64-derived predicates on subnormal operands.
    return jnp.where(jnp.abs(y) > fmt.xmax, jnp.sign(y) * jnp.inf, y)


# ---------------------------------------------------------------------------
# Pallas kernels
# ---------------------------------------------------------------------------

#: Block edge for matrix tiles. 128 matches the MXU systolic-array edge; a
#: (128,128) f64 tile is 128 KiB.
BLOCK = 128
#: Block length for vector kernels.
VBLOCK = 1024


def _chop_kernel(x_ref, o_ref, *, fmt: Format):
    o_ref[...] = chop_bits(x_ref[...], fmt)


def _ceil_to(n: int, b: int) -> int:
    return -(-n // b) * b


@functools.partial(jax.jit, static_argnames=("fmt_name",))
def pallas_chop(x: jax.Array, fmt_name: str) -> jax.Array:
    """Elementwise chop of a 1-D or 2-D f64 array via a tiled Pallas kernel."""
    fmt = FORMATS[fmt_name]
    if fmt.name == "fp64":
        return x
    if x.ndim == 1:
        n = x.shape[0]
        blk = min(VBLOCK, _ceil_to(n, 8))
        np_ = _ceil_to(n, blk)
        xp = jnp.pad(x, (0, np_ - n))
        out = pl.pallas_call(
            functools.partial(_chop_kernel, fmt=fmt),
            out_shape=jax.ShapeDtypeStruct((np_,), x.dtype),
            grid=(np_ // blk,),
            in_specs=[pl.BlockSpec((blk,), lambda i: (i,))],
            out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
            interpret=True,
        )(xp)
        return out[:n]
    assert x.ndim == 2
    m, n = x.shape
    bm = min(BLOCK, _ceil_to(m, 8))
    bn = min(BLOCK, _ceil_to(n, 8))
    mp, np_ = _ceil_to(m, bm), _ceil_to(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, np_ - n)))
    out = pl.pallas_call(
        functools.partial(_chop_kernel, fmt=fmt),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        grid=(mp // bm, np_ // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(xp)
    return out[:m, :n]


def _matvec_kernel(a_ref, x_ref, o_ref, *, fmt: Format, nj: int):
    """One (row-block, col-block) step of y += chop(A_blk) @ chop(x_blk).

    Grid iterates column blocks innermost; o_ref accumulates in f64 across
    the column dimension (the revisiting-output pattern); the final chop of
    y happens on the last column block.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = chop_bits(a_ref[...], fmt)
    x = chop_bits(x_ref[...], fmt)
    o_ref[...] += a @ x

    @pl.when(j == nj - 1)
    def _finalize():
        o_ref[...] = chop_bits(o_ref[...], fmt)


@functools.partial(jax.jit, static_argnames=("fmt_name",))
def pallas_chopped_matvec(a: jax.Array, x: jax.Array, fmt_name: str) -> jax.Array:
    """y = chop(chop(A) @ chop(x)) with f64 block accumulation.

    Matches MXU semantics: low-precision operands, wide accumulator,
    result stored back in the working format (DESIGN.md §3/§5 fidelity
    note). For fmt = fp64 this is a plain f64 GEMV.
    """
    fmt = FORMATS[fmt_name]
    m, n = a.shape
    bm = min(BLOCK, _ceil_to(m, 8))
    bn = min(BLOCK, _ceil_to(n, 8))
    mp, np_ = _ceil_to(m, bm), _ceil_to(n, bn)
    ap = jnp.pad(a, ((0, mp - m), (0, np_ - n)))
    xp = jnp.pad(x, (0, np_ - n))
    nj = np_ // bn
    out = pl.pallas_call(
        functools.partial(_matvec_kernel, fmt=fmt, nj=nj),
        out_shape=jax.ShapeDtypeStruct((mp,), a.dtype),
        grid=(mp // bm, nj),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i, j: (i,)),
        interpret=True,
    )(ap, xp)
    return out[:m]


def _outer_update_kernel(m_ref, r_ref, a_ref, o_ref, *, fmt: Format):
    """Rank-1 Schur-complement update: o = chop(A - chop(m r^T)).

    The hot elementwise op of right-looking LU; operands are already in
    the working format (they live in the chopped matrix), the update and
    the result are rounded back to the format — i.e. storage rounding per
    step, the standard simulation of a low-precision LU.
    """
    upd = chop_bits(m_ref[...][:, None] * r_ref[...][None, :], fmt)
    o_ref[...] = chop_bits(a_ref[...] - upd, fmt)


@functools.partial(jax.jit, static_argnames=("fmt_name",))
def pallas_outer_update(mcol: jax.Array, rrow: jax.Array, a: jax.Array, fmt_name: str) -> jax.Array:
    """A - outer(mcol, rrow), chopped per-op, tiled (the LU hot path)."""
    fmt = FORMATS[fmt_name]
    if fmt.name == "fp64":
        return a - jnp.outer(mcol, rrow)
    m, n = a.shape
    bm = min(BLOCK, _ceil_to(m, 8))
    bn = min(BLOCK, _ceil_to(n, 8))
    mp, np_ = _ceil_to(m, bm), _ceil_to(n, bn)
    ap = jnp.pad(a, ((0, mp - m), (0, np_ - n)))
    mp_v = jnp.pad(mcol, (0, mp - m))
    rp = jnp.pad(rrow, (0, np_ - n))
    out = pl.pallas_call(
        functools.partial(_outer_update_kernel, fmt=fmt),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(mp_v, rp, ap)
    return out[:m, :n]
