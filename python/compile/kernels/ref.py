"""Pure-jnp / numpy oracle for the chop kernel and chopped operations.

Independent implementation (frexp-based, vs. the bit-twiddling kernel in
``chop.py``) used as the correctness reference in pytest. Also provides a
strict Pychop-style *per-op rounding* matvec used to validate the
f64-accumulate emulation mode at the solver level (DESIGN.md §5 fidelity
note).
"""

from __future__ import annotations

import numpy as np

from .chop import FORMATS, Format


def chop_ref(x, fmt: Format | str) -> np.ndarray:
    """Round f64 array ``x`` to format ``fmt`` (RNE), frexp-based oracle."""
    if isinstance(fmt, str):
        fmt = FORMATS[fmt]
    x = np.asarray(x, dtype=np.float64)
    if fmt.name == "fp64":
        return x.copy()
    out = x.copy()
    finite = np.isfinite(x) & (x != 0)
    xs = x[finite]
    # x = m * 2**E with 0.5 <= |m| < 1  =>  true exponent e = E - 1
    _, E = np.frexp(xs)
    e = E - 1
    e_eff = np.maximum(e, fmt.emin)
    q = np.ldexp(1.0, (e_eff - (fmt.t - 1)).astype(np.int64))
    with np.errstate(over="ignore", invalid="ignore"):
        y = np.round(xs / q) * q  # numpy round is ties-to-even
        y = np.where(np.abs(y) > fmt.xmax, np.sign(y) * np.inf, y)
    out[finite] = y
    return out


def chopped_matvec_ref(a, x, fmt: Format | str) -> np.ndarray:
    """Oracle for pallas_chopped_matvec: chop operands, f64 accumulate,
    chop the result."""
    a = chop_ref(a, fmt)
    x = chop_ref(x, fmt)
    return chop_ref(a @ x, fmt)


def chopped_matvec_perop_ref(a, x, fmt: Format | str) -> np.ndarray:
    """Strict Pychop semantics: every scalar multiply and add is rounded.

    O(n^2) python loop — only for validation on small sizes.
    """
    if isinstance(fmt, str):
        fmt = FORMATS[fmt]
    a = chop_ref(a, fmt)
    x = chop_ref(x, fmt)
    m, n = a.shape
    y = np.zeros(m)
    for j in range(n):
        prod = chop_ref(a[:, j] * x[j], fmt)
        y = chop_ref(y + prod, fmt)
    return y


def lu_ref(a):
    """Plain f64 LU with partial pivoting (packed), for comparison with the
    fp64 artifact. Returns (LU, piv) in the same layout as model.lu_factor."""
    a = np.array(a, dtype=np.float64)
    n = a.shape[0]
    piv = np.zeros(n, dtype=np.int32)
    for k in range(n):
        p = k + int(np.argmax(np.abs(a[k:, k])))
        piv[k] = p
        if p != k:
            a[[k, p], :] = a[[p, k], :]
        if a[k, k] != 0:
            a[k + 1 :, k] /= a[k, k]
            a[k + 1 :, k + 1 :] -= np.outer(a[k + 1 :, k], a[k, k + 1 :])
    return a, piv


def lu_solve_ref(lu, piv, b):
    """Solve with the packed LU from lu_ref (f64)."""
    n = lu.shape[0]
    y = np.array(b, dtype=np.float64)
    for k in range(n):
        p = piv[k]
        if p != k:
            y[[k, p]] = y[[p, k]]
    for i in range(1, n):
        y[i] -= lu[i, :i] @ y[:i]
    x = y
    for i in range(n - 1, -1, -1):
        x[i] = (x[i] - lu[i, i + 1 :] @ x[i + 1 :]) / lu[i, i]
    return x
