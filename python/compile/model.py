"""Layer-2: the mixed-precision GMRES-IR compute graphs (paper Alg. 2).

Each precision-controlled step of GMRES-based iterative refinement is a
separate jax function, parameterized (statically) by the emulated
floating-point format and lowered once per (op, format, size-bucket) by
``aot.py``. The Rust L3 coordinator owns the outer refinement loop and
calls these artifacts through PJRT; Python never runs at solve time.

Ops
---
* ``lu_factor(A)``        -> (LU packed, piv, ok)      precision u_f
* ``lu_solve(LU, piv, b)``-> x                         precision u_f / u_g
* ``residual(A, x, b)``   -> r = b - A x               precision u_r
* ``gmres(A, LU, piv, r, tol, maxit)``
                          -> (z, iters, relres, ok)    precision u_g
  (left-preconditioned by the LU factors, MGS-Arnoldi + Givens; the
  preconditioner is applied in u_g, matching paper §4.2)

Emulation semantics: operands and every stored intermediate are rounded
to the target format; dot products accumulate in f64 (MXU/tensor-core
style — DESIGN.md §5 fidelity note). The elementwise/matvec hot paths go
through the Pallas kernels in ``kernels/chop.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from .kernels.chop import (
    FORMATS,
    chop_bits,
    pallas_chop,
    pallas_chopped_matvec,
    pallas_outer_update,
)

jax.config.update("jax_enable_x64", True)

#: Maximum Krylov dimension of one (non-restarted) inner GMRES solve.
#: Paper experiments observe 2–21 average inner iterations; 50 gives
#: ample headroom while keeping the V buffer small (50 x n f64).
GMRES_MAX_M = 50


def _chop(x, fmt_name: str):
    """Scalar / small-array chop (no Pallas dispatch overhead)."""
    return chop_bits(x, FORMATS[fmt_name])


# ---------------------------------------------------------------------------
# LU factorization with partial pivoting, right-looking, storage-rounded
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("fmt",))
def lu_factor(a: jax.Array, fmt: str):
    """Packed LU with partial pivoting in emulated precision ``fmt``.

    Returns ``(LU, piv, ok)`` where LU packs the unit-lower L (below the
    diagonal) and U; ``piv[k]`` is the row swapped with k at step k;
    ``ok`` is 0 if the factorization hit a zero/non-finite pivot (e.g.
    overflow in a narrow format) — the L3 coordinator treats that as the
    failure case of the paper's reward penalty.
    """
    n = a.shape[0]
    a = pallas_chop(a, fmt)
    idx = jnp.arange(n)

    def body(k, carry):
        a, piv, ok = carry
        col = jnp.abs(a[:, k])
        col = jnp.where(idx >= k, col, -jnp.inf)
        # NaNs must not win the pivot search:
        col = jnp.where(jnp.isnan(col), -jnp.inf, col)
        p = jnp.argmax(col).astype(jnp.int32)
        piv = piv.at[k].set(p)
        rk, rp = a[k], a[p]
        a = a.at[k].set(rp).at[p].set(rk)
        pivv = a[k, k]
        ok = ok & (pivv != 0.0) & jnp.isfinite(pivv)
        safe = jnp.where((pivv == 0.0) | ~jnp.isfinite(pivv), 1.0, pivv)
        mcol = _chop(a[:, k] / safe, fmt)
        mcol = jnp.where(idx > k, mcol, 0.0)
        rowk = jnp.where(idx > k, a[k, :], 0.0)
        upd = pallas_outer_update(mcol, rowk, a, fmt)
        sel = (idx[:, None] > k) & (idx[None, :] > k)
        a = jnp.where(sel, upd, a)
        a = a.at[:, k].set(jnp.where(idx > k, mcol, a[:, k]))
        return a, piv, ok

    a, piv, ok = lax.fori_loop(
        0, n, body, (a, jnp.zeros(n, jnp.int32), jnp.bool_(True))
    )
    return a, piv, ok.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Triangular solves with the packed LU
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("fmt",))
def lu_solve(lu: jax.Array, piv: jax.Array, b: jax.Array, fmt: str):
    """x = U^{-1} L^{-1} P b in emulated precision ``fmt``.

    Forward/backward substitution; each row's dot product accumulates in
    f64 and the stored component is rounded (storage rounding per step).
    """
    n = lu.shape[0]
    idx = jnp.arange(n)
    b = _chop(b, fmt)

    def swap(k, y):
        p = piv[k]
        yk, yp = y[k], y[p]
        return y.at[k].set(yp).at[p].set(yk)

    y = lax.fori_loop(0, n, swap, b)

    def fwd(i, y):
        row = jnp.where(idx < i, lu[i], 0.0)
        s = _chop(row @ y, fmt)
        return y.at[i].set(_chop(y[i] - s, fmt))

    y = lax.fori_loop(0, n, fwd, y)

    def bwd(ii, x):
        i = n - 1 - ii
        row = jnp.where(idx > i, lu[i], 0.0)
        s = _chop(row @ x, fmt)
        d = jnp.where(lu[i, i] == 0.0, 1.0, lu[i, i])
        v = _chop((x[i] - s) / d, fmt)
        v = jnp.where(lu[i, i] == 0.0, jnp.nan, v)
        return x.at[i].set(v)

    return lax.fori_loop(0, n, bwd, y)


# ---------------------------------------------------------------------------
# Residual (precision u_r) — the Pallas chopped-GEMV hot path
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("fmt",))
def residual(a: jax.Array, x: jax.Array, b: jax.Array, fmt: str):
    """r = b - A x computed in emulated precision ``fmt``."""
    ax = pallas_chopped_matvec(a, x, fmt)
    return _chop(_chop(b, fmt) - ax, fmt)


# ---------------------------------------------------------------------------
# Preconditioned GMRES (precision u_g)
# ---------------------------------------------------------------------------


def _apply_prec(lu, piv, v, fmt):
    """M^{-1} v = U^{-1} L^{-1} P v, in precision fmt (paper §4.2: the
    preconditioner is applied in u_g)."""
    return lu_solve(lu, piv, v, fmt)


@functools.partial(jax.jit, static_argnames=("fmt",))
def gmres(
    a: jax.Array,
    lu: jax.Array,
    piv: jax.Array,
    r: jax.Array,
    tol: jax.Array,
    maxit: jax.Array,
    fmt: str,
):
    """Solve M^{-1} A z = M^{-1} r by (non-restarted) MGS-Arnoldi GMRES.

    All vector storage is rounded to ``fmt``; reductions accumulate in
    f64. Givens rotations maintain the QR of the small Hessenberg matrix,
    giving the residual estimate for the while-loop exit test
    ``|g[j+1]| <= tol * beta`` (relative to the preconditioned residual).

    Returns ``(z, iters, relres, ok)``.
    """
    n = a.shape[0]
    m = min(GMRES_MAX_M, n)
    maxit = jnp.minimum(maxit.astype(jnp.int32), m)

    r0 = _apply_prec(lu, piv, r, fmt)
    beta = _chop(jnp.sqrt(r0 @ r0), fmt)
    ok0 = jnp.isfinite(beta) & (beta > 0.0)
    safe_beta = jnp.where(ok0, beta, 1.0)

    V = jnp.zeros((m + 1, n))
    V = V.at[0].set(_chop(r0 / safe_beta, fmt))
    H = jnp.zeros((m + 1, m))
    cs = jnp.zeros(m)
    sn = jnp.zeros(m)
    g = jnp.zeros(m + 1).at[0].set(beta)

    def cond(state):
        j, V, H, cs, sn, g, res, ok, brk, best, stall = state
        # stall guard: mirrors the native backend — stop after 3
        # consecutive iterations without >10% improvement of the best
        # residual estimate (precision-floor detection in low u_g).
        return (j < maxit) & (res > tol * safe_beta) & ok & ~brk & (stall < 3)

    def body(state):
        j, V, H, cs, sn, g, res, ok, brk, best, stall = state
        w = pallas_chopped_matvec(a, V[j], fmt)
        w = _apply_prec(lu, piv, w, fmt)

        # Modified Gram-Schmidt against v_0..v_j (dynamic bound fori).
        def mgs(i, carry):
            w, h = carry
            hij = _chop(V[i] @ w, fmt)
            w = _chop(w - hij * V[i], fmt)
            return w, h.at[i].set(hij)

        w, hcol = lax.fori_loop(0, j + 1, mgs, (w, jnp.zeros(m + 1)))
        hj1 = _chop(jnp.sqrt(w @ w), fmt)
        hcol = hcol.at[j + 1].set(hj1)
        happy = hj1 <= 1e-300  # exact breakdown => solution in span(V)
        safe_h = jnp.where(happy, 1.0, hj1)
        V = V.at[j + 1].set(_chop(w / safe_h, fmt))

        # Apply the accumulated Givens rotations to the new column.
        def rot(i, h):
            t1 = cs[i] * h[i] + sn[i] * h[i + 1]
            t2 = -sn[i] * h[i] + cs[i] * h[i + 1]
            return h.at[i].set(t1).at[i + 1].set(t2)

        hcol = lax.fori_loop(0, j, rot, hcol)

        # New rotation annihilating H[j+1, j].
        denom = jnp.sqrt(hcol[j] ** 2 + hcol[j + 1] ** 2)
        denom_safe = jnp.where(denom == 0.0, 1.0, denom)
        c = jnp.where(denom == 0.0, 1.0, hcol[j] / denom_safe)
        s = jnp.where(denom == 0.0, 0.0, hcol[j + 1] / denom_safe)
        cs = cs.at[j].set(c)
        sn = sn.at[j].set(s)
        hcol = hcol.at[j].set(denom).at[j + 1].set(0.0)
        gj = g[j]
        g = g.at[j].set(c * gj).at[j + 1].set(-s * gj)
        H = H.at[:, j].set(hcol[: m + 1])

        res = jnp.abs(g[j + 1])
        ok = ok & jnp.isfinite(res) & jnp.all(jnp.isfinite(hcol))
        improved = res < 0.9 * best
        best = jnp.where(improved, res, best)
        stall = jnp.where(improved, 0, stall + 1)
        return j + 1, V, H, cs, sn, g, res, ok, happy, best, stall

    state0 = (
        jnp.int32(0), V, H, cs, sn, g, beta, ok0, jnp.bool_(False), beta,
        jnp.int32(0),
    )
    j, V, H, cs, sn, g, res, ok, _, _, _ = lax.while_loop(cond, body, state0)

    # Back-substitute the j x j triangular system H y = g (masked to j).
    def bwd(ii, y):
        i = j - 1 - ii
        idxm = jnp.arange(m)
        row = jnp.where((idxm > i) & (idxm < j), H[i, :], 0.0)
        s = row @ y
        d = jnp.where(H[i, i] == 0.0, 1.0, H[i, i])
        return y.at[i].set((g[i] - s) / d)

    y = lax.fori_loop(0, j, bwd, jnp.zeros(m))
    y = jnp.where(jnp.arange(m) < j, y, 0.0)

    # z = V[:m].T @ y  (f64 accumulate, then round to fmt).
    z = _chop(V[:m].T @ y, fmt)
    relres = res / safe_beta
    ok = ok & ok0 & jnp.all(jnp.isfinite(z))
    return z, j, relres, ok.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Convenience composition used by tests (one full IR solve in jax, mirroring
# what the Rust coordinator does artifact-by-artifact).
# ---------------------------------------------------------------------------


def gmres_ir_reference(
    a,
    b,
    fmts: tuple[str, str, str, str],
    tol_gmres: float = 1e-10,
    tol_update: float = 1e-14,
    max_outer: int = 10,
    stag_ratio: float = 0.9,
):
    """Run full GMRES-IR in jax with action (u_f, u, u_g, u_r).

    Test-only composition (the production path drives the four artifacts
    from Rust); implements the paper's stopping criteria (14)-(16):
    convergence on relative update norm, stagnation on update ratio, and
    the outer-iteration cap. Returns (x, outer_iters, total_gmres_iters,
    ok).
    """
    uf, u, ug, ur = fmts
    lu, piv, okf = lu_factor(a, uf)
    x = lu_solve(lu, piv, b, uf)
    total_inner = 0
    outer = 0
    ok = bool(okf)
    if not ok:
        return x, 0, 0, False
    prev_nz = None
    for _ in range(max_outer):
        r = residual(a, x, b, ur)
        z, it, _relres, okg = gmres(
            a, lu, piv, r, jnp.float64(tol_gmres), jnp.int32(GMRES_MAX_M), ug
        )
        x = _chop(x + z, u)
        total_inner += int(it)
        outer += 1
        ok = ok and bool(okg)
        nz = float(jnp.max(jnp.abs(z)))
        nx = float(jnp.max(jnp.abs(x)))
        if nx > 0 and nz / nx <= tol_update:
            break  # eq. (14): converged
        if prev_nz is not None and prev_nz > 0 and nz / prev_nz >= stag_ratio:
            break  # eq. (15): stagnated
        prev_nz = nz
    return x, outer, total_inner, ok
