#!/usr/bin/env python3
"""Socket smoke driver for the pallas-serve daemon (CI `serve` job).

Speaks the newline-delimited JSON protocol directly over TCP — no
project imports, stdlib only — and walks the daemon through the full
ISSUE 7 lifecycle:

  1. connect (with retries while the daemon boots) and ping;
  2. snapshot the boot policy so reload has bytes to read;
  3. stream the first half of the solve requests (every response must
     be ok);
  4. one zero-downtime hot-reload (policy version must bump by one);
  5. shadow-load a candidate policy, stream the second half (shadow
     scoring rides along), then one promotion: the un-forced attempt
     must be rejected while the candidate lacks evidence, the forced
     one must swap it live;
  6. two-tenant router scenario (ISSUE 8): register "acme" with a
     3-request quota and "globex" unlimited, solve through both
     partitions, assert the 4th acme request is a typed
     rejected[quota], and check the per-tenant stats ledgers stay
     isolated;
  7. dump the final stats payload to --stats-out and assert the
     counters (solves_ok, reloads, promotions, routed/rejected);
  8. clean shutdown.

Exits non-zero on any failed request, missed counter, or protocol
violation.
"""

import argparse
import json
import socket
import sys
import time


def die(msg):
    print(f"serve_smoke: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


class Daemon:
    """One TCP connection; each call() is a strict request/response."""

    def __init__(self, addr, retries):
        host, port = addr.rsplit(":", 1)
        last = None
        for _ in range(retries):
            try:
                self.sock = socket.create_connection((host, int(port)), timeout=60)
                break
            except OSError as e:
                last = e
                time.sleep(0.25)
        else:
            die(f"could not connect to {addr} after {retries} attempts: {last}")
        self.rfile = self.sock.makefile("r", encoding="utf-8", newline="\n")

    def call(self, obj):
        self.sock.sendall((json.dumps(obj) + "\n").encode("utf-8"))
        line = self.rfile.readline()
        if not line:
            die("daemon closed the connection without responding")
        return json.loads(line)

    def admin(self, op, **extra):
        return self.call({"op": op, **extra})


def lcg(seed):
    """Tiny deterministic uniform stream in [0, 1) — no numpy needed."""
    state = (seed & 0x7FFFFFFF) or 1
    while True:
        state = (1103515245 * state + 12345) & 0x7FFFFFFF
        yield state / 0x80000000


def dense_request(req_id, n, seed):
    """Diagonally dominant dense system as a solve-request object."""
    r = lcg(seed)
    a = []
    for i in range(n):
        row = [next(r) - 0.5 for _ in range(n)]
        row[i] += float(n)
        a.extend(row)
    b = [next(r) for _ in range(n)]
    return {"op": "solve", "id": req_id, "n": n, "a": a, "b": b}


def routed_request(req_id, n, seed, tenant, lane):
    """A solve request carrying the ISSUE 8 routing fields."""
    req = dense_request(req_id, n, seed)
    req["tenant"] = tenant
    req["lane"] = lane
    req["deadline_ms"] = 30000
    return req


def expect_ok(resp, what):
    if not resp.get("ok", False):
        die(f"{what} rejected: {resp}")
    return resp


def two_tenant_scenario(c, n):
    """Quota + isolation over the wire; returns (routed_ok, routed_rejected)."""
    expect_ok(c.admin("tenant", tenant="acme", quota=3), "tenant acme")
    expect_ok(c.admin("tenant", tenant="globex"), "tenant globex")

    for i in range(3):
        resp = c.call(routed_request(i, n, 900 + i, "acme", "interactive"))
        expect_ok(resp, f"acme solve #{i}")
    over = c.call(routed_request(3, n, 903, "acme", "interactive"))
    if over.get("ok", False):
        die(f"4th acme request must exceed the 3-request quota: {over}")
    if over.get("rejected") != "quota":
        die(f"over-quota rejection must be typed rejected[quota]: {over}")

    for i in range(2):
        resp = c.call(routed_request(10 + i, n, 950 + i, "globex", "batch"))
        expect_ok(resp, f"globex solve #{i}")

    tenants = expect_ok(c.admin("stats"), "stats")["router"]["tenants"]
    acme, globex = tenants["acme"], tenants["globex"]
    if acme["admitted"]["interactive"] != 3 or acme["shed"]["quota"] != 1:
        die(f"acme ledger must read 3 admitted / 1 quota-shed: {acme}")
    if acme["quota_remaining"] != 0:
        die(f"acme must have spent its whole quota: {acme}")
    if globex["admitted"]["batch"] != 2 or globex["shed"]["quota"] != 0:
        die(f"globex ledger must read 2 admitted / 0 shed: {globex}")
    # isolation: each tenant's counters see only its own traffic
    if acme["counters"]["solves_ok"] != 3 or globex["counters"]["solves_ok"] != 2:
        die(f"per-tenant solve counters must stay isolated: {acme} / {globex}")
    if globex["fingerprint"] == "" or acme["fingerprint"] == "":
        die("per-tenant learner fingerprints must be reported")
    return 5, 1


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--addr", default="127.0.0.1:7747")
    p.add_argument("--requests", type=int, default=50)
    p.add_argument("--n", type=int, default=8, help="system size per request")
    p.add_argument("--candidate", required=True, help="policy JSON for the shadow arm")
    p.add_argument("--stats-out", required=True, help="where to dump the final stats payload")
    p.add_argument("--connect-retries", type=int, default=80)
    args = p.parse_args()

    c = Daemon(args.addr, args.connect_retries)
    ping = expect_ok(c.admin("ping"), "ping")
    v0 = ping["policy_version"]

    expect_ok(c.admin("snapshot"), "snapshot")

    half = args.requests // 2
    for i in range(half):
        resp = c.call(dense_request(i, args.n, 100 + i))
        expect_ok(resp, f"solve #{i}")

    # zero-downtime hot-reload: version bumps by exactly one
    expect_ok(c.admin("reload"), "reload")
    v1 = expect_ok(c.admin("ping"), "ping")["policy_version"]
    if v1 != v0 + 1:
        die(f"reload must bump the policy version once ({v0} -> {v1})")

    # shadow arm: load a candidate, let scoring ride the second half
    expect_ok(c.admin("shadow-load", path=args.candidate), "shadow-load")
    for i in range(half, args.requests):
        resp = c.call(dense_request(i, args.n, 100 + i))
        expect_ok(resp, f"solve #{i}")

    # without evidence the promotion gate must hold...
    bare = c.admin("promote")
    if bare.get("ok", False):
        die(f"un-forced promote must be rejected without a cleared win-rate: {bare}")
    # ...and the forced promotion must swap the candidate live
    forced = expect_ok(c.admin("promote", force=True), "forced promote")
    if forced["policy_version"] != v1 + 1:
        die(f"promotion must bump the policy version ({v1} -> {forced['policy_version']})")

    # multi-tenant router scenario: quotas, typed rejection, isolation
    routed_ok, routed_rejected = two_tenant_scenario(c, args.n)

    stats = expect_ok(c.admin("stats"), "stats")
    with open(args.stats_out, "w", encoding="utf-8") as f:
        json.dump(stats, f, indent=2, sort_keys=True)
    counters = stats["counters"]
    total_ok = args.requests + routed_ok
    if counters["solves_ok"] != total_ok:
        die(f"expected {total_ok} ok solves, got {counters['solves_ok']}")
    if counters["routed"] != routed_ok + routed_rejected:
        die(f"expected {routed_ok + routed_rejected} routed requests, got {counters['routed']}")
    if counters["rejected_quota"] != routed_rejected:
        die(f"expected {routed_rejected} quota rejection, got {counters['rejected_quota']}")
    if counters["reloads"] < 1:
        die(f"expected at least one reload, got {counters['reloads']}")
    if counters["promotions"] != 1:
        die(f"expected exactly one promotion, got {counters['promotions']}")

    expect_ok(c.admin("shutdown"), "shutdown")
    print(
        f"serve_smoke: OK — {total_ok} solves across 3 tenants, policy v{v0} -> "
        f"v{forced['policy_version']} (one reload + one promotion + one quota shed), "
        f"stats in {args.stats_out}"
    )


if __name__ == "__main__":
    main()
